"""Ragged packed-prefill kernel vs the dense reference oracle.

Every case checks the Pallas kernel (interpret mode) against BOTH the
packed oracle (ref_ragged_prefill) and a per-sequence call to the dense
oracle (ref_flash_attn) — the latter is the correctness contract the
dense (L, B) path already satisfies.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ragged_prefill import ragged_prefill_attn
from repro.kernels.ref import ref_flash_attn, ref_ragged_prefill

TOL = dict(rtol=2e-5, atol=2e-5)


def make_case(lens, hists, hq, hkv, d, s, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    b, t = len(lens), int(sum(lens))
    cu = np.zeros(b + 1, np.int32)
    cu[1:] = np.cumsum(lens)
    q = rng.standard_normal((t, hq, d)).astype(dtype)
    k = rng.standard_normal((b, s, hkv, d)).astype(dtype)
    v = rng.standard_normal((b, s, hkv, d)).astype(dtype)
    off = np.asarray(hists, np.int32)
    kvl = off + np.asarray(lens, np.int32)
    return q, k, v, cu, off, kvl


def run_kernel(q, k, v, cu, off, kvl, **kw):
    return np.asarray(ragged_prefill_attn(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(cu),
        jnp.asarray(off), jnp.asarray(kvl), interpret=True, **kw))


def check_against_dense(out, q, k, v, cu, off, kvl, causal=True):
    """Rows of each sequence must equal the dense per-sequence oracle."""
    for i in range(len(off)):
        qi = q[cu[i]:cu[i + 1]][None]
        dense = np.asarray(ref_flash_attn(
            jnp.asarray(qi), jnp.asarray(k[i:i + 1]), jnp.asarray(v[i:i + 1]),
            q_offsets=jnp.asarray(off[i:i + 1]),
            kv_lengths=jnp.asarray(kvl[i:i + 1]), causal=causal))[0]
        np.testing.assert_allclose(out[cu[i]:cu[i + 1]], dense, **TOL)


@pytest.mark.parametrize("lens,hq,hkv,d,s", [
    ([7, 23, 61, 12], 4, 4, 16, 128),    # MHA mixed lengths
    ([7, 23, 61, 12], 8, 2, 16, 128),    # GQA rep=4
    ([1, 1, 1], 4, 1, 8, 32),            # single-token sequences
    ([64], 4, 2, 16, 64),                # one block-aligned sequence
    ([33, 31], 8, 4, 32, 64),            # boundary inside a q block
])
def test_ragged_matches_dense(lens, hq, hkv, d, s):
    q, k, v, cu, off, kvl = make_case(lens, [0] * len(lens), hq, hkv, d, s)
    out = run_kernel(q, k, v, cu, off, kvl, block_q=32, block_k=32)
    check_against_dense(out, q, k, v, cu, off, kvl)


def test_ragged_decode_segments():
    """Continuous batching: length-1 decode segments with large history
    offsets attend over exactly offset + 1 keys — mixed freely with
    prefill segments in one stream."""
    lens = [1, 1, 1]
    hists = [97, 0, 41]                       # deep, fresh, mid histories
    q, k, v, cu, off, kvl = make_case(lens, hists, 8, 2, 16, 128, seed=21)
    out = run_kernel(q, k, v, cu, off, kvl, block_q=16, block_k=32)
    check_against_dense(out, q, k, v, cu, off, kvl)
    # poisoning keys past each row's offset + 1 must not change anything:
    # the causal frontier caps the kv scan at the decode row's position
    k2, v2 = k.copy(), v.copy()
    for i, h in enumerate(hists):
        k2[i, h + 1:] = 1e3
        v2[i, h + 1:] = -1e3
    out2 = run_kernel(q, k2, v2, cu, off, kvl, block_q=16, block_k=32)
    np.testing.assert_allclose(out2, out, **TOL)


def test_ragged_mixed_prefill_and_decode_segments():
    """The mixed-step stream shape: short prefills, a re-prefill chunk,
    and decode rows side by side in one ragged call."""
    lens = [7, 1, 23, 1, 12, 1]
    hists = [0, 55, 0, 9, 30, 101]            # decode rows at 1-lengths
    q, k, v, cu, off, kvl = make_case(lens, hists, 8, 4, 16, 128, seed=23)
    out = run_kernel(q, k, v, cu, off, kvl, block_q=32, block_k=64)
    check_against_dense(out, q, k, v, cu, off, kvl)
    ref = np.asarray(ref_ragged_prefill(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(cu),
        jnp.asarray(off), jnp.asarray(kvl)))
    np.testing.assert_allclose(out, ref, **TOL)


def test_ragged_reprefill_offsets():
    """Re-prefill: queries start at history offsets inside the cache."""
    lens, hists = [5, 17, 9], [12, 0, 70]
    q, k, v, cu, off, kvl = make_case(lens, hists, 8, 2, 16, 128, seed=3)
    out = run_kernel(q, k, v, cu, off, kvl, block_q=16, block_k=32)
    check_against_dense(out, q, k, v, cu, off, kvl)


def test_ragged_noncausal():
    lens = [6, 14]
    q, k, v, cu, off, kvl = make_case(lens, [0, 0], 4, 4, 16, 32, seed=5)
    out = run_kernel(q, k, v, cu, off, kvl, causal=False,
                     block_q=8, block_k=16)
    check_against_dense(out, q, k, v, cu, off, kvl, causal=False)


def test_ragged_oracle_agreement():
    """Kernel vs the packed oracle on an irregular blocking."""
    lens, hists = [7, 23, 61, 12], [3, 0, 11, 40]
    q, k, v, cu, off, kvl = make_case(lens, hists, 8, 4, 16, 128, seed=7)
    out = run_kernel(q, k, v, cu, off, kvl, block_q=32, block_k=64)
    ref = np.asarray(ref_ragged_prefill(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(cu),
        jnp.asarray(off), jnp.asarray(kvl)))
    np.testing.assert_allclose(out, ref, **TOL)


def test_ragged_bucket_tail_padding():
    """Stream padded past cu[-1] (token-bucket tail): pad rows yield 0
    and real rows are unaffected."""
    lens = [7, 12]
    q, k, v, cu, off, kvl = make_case(lens, [0, 0], 4, 2, 16, 64, seed=9)
    t_bucket = 64                              # bucketed stream length
    qp = np.zeros((t_bucket,) + q.shape[1:], q.dtype)
    qp[:q.shape[0]] = q
    qp[q.shape[0]:] = 1e3                      # poison pad rows
    out = run_kernel(qp, k, v, cu, off, kvl, block_q=32, block_k=32)
    check_against_dense(out[:sum(lens)], q, k, v, cu, off, kvl)
    np.testing.assert_array_equal(out[sum(lens):], 0.0)


def test_ragged_padded_empty_sequences():
    """B padded with empty sequences (cu repeats): they contribute
    nothing and break nothing — the executor pads B_max this way."""
    lens = [9, 30]
    q, k, v, cu, off, kvl = make_case(lens, [4, 0], 4, 2, 16, 64, seed=11)
    b_max = 5
    cu_p = np.concatenate([cu, np.full(b_max - len(lens), cu[-1], np.int32)])
    k_p = np.concatenate([k, np.zeros((b_max - len(lens),) + k.shape[1:],
                                      k.dtype)])
    v_p = np.concatenate([v, np.zeros_like(k_p[:b_max - len(lens)])])
    off_p = np.concatenate([off, np.zeros(b_max - len(lens), np.int32)])
    kvl_p = np.concatenate([kvl, np.zeros(b_max - len(lens), np.int32)])
    out = run_kernel(q, k_p, v_p, cu_p, off_p, kvl_p, block_q=16, block_k=32)
    check_against_dense(out, q, k, v, cu, off, kvl)


def test_ragged_bfloat16():
    lens = [7, 23, 12]
    q, k, v, cu, off, kvl = make_case(lens, [0, 5, 0], 8, 2, 16, 64, seed=13)
    qb, kb, vb = (jnp.asarray(a).astype(jnp.bfloat16) for a in (q, k, v))
    out = np.asarray(ragged_prefill_attn(
        qb, kb, vb, jnp.asarray(cu), jnp.asarray(off), jnp.asarray(kvl),
        block_q=16, block_k=32, interpret=True).astype(jnp.float32))
    ref = np.asarray(ref_ragged_prefill(
        qb, kb, vb, jnp.asarray(cu), jnp.asarray(off),
        jnp.asarray(kvl)).astype(jnp.float32))
    np.testing.assert_allclose(out, ref, rtol=5e-2, atol=5e-2)
