"""MoE dispatch vs dense oracle; Mamba2 SSD properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.models.layers import ParamBuilder
from repro.models.mamba import init_mamba, mamba_layer, ssd_chunked
from repro.models.moe import init_moe, moe_dense_reference, moe_layer
from repro.kernels.ref import ref_ssd_scan

KEY = jax.random.key(5)


def moe_params(d=32, ff=16, e=4):
    pb = ParamBuilder(KEY, jnp.float32)
    init_moe(pb, d, ff, e)
    return pb.params


def test_moe_dispatch_matches_dense_at_high_capacity():
    p = moe_params()
    x = jax.random.normal(KEY, (2, 8, 32))
    want, aux_w = moe_dense_reference(p, x, top_k=2)
    got, aux_g = moe_layer(p, x, top_k=2, capacity_factor=8.0)  # no drops
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
    assert float(aux_w) == pytest.approx(float(aux_g), rel=1e-5)


def test_moe_capacity_drops_degrade_gracefully():
    p = moe_params()
    x = jax.random.normal(KEY, (2, 32, 32))
    got, _ = moe_layer(p, x, top_k=2, capacity_factor=0.25)
    assert not jnp.isnan(got).any()        # drops zero out, never NaN


def test_moe_aux_loss_balanced_router_is_one():
    # uniform router → aux = E * Σ (1/E)(1/E) * E = 1 exactly
    p = moe_params()
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(KEY, (4, 16, 32))
    _, aux = moe_dense_reference(p, x, top_k=2)
    assert float(aux) == pytest.approx(1.0, abs=0.3)


@given(chunk=st.sampled_from([4, 8, 16, 32]))
@settings(max_examples=8)
def test_ssd_chunk_size_invariance(chunk):
    ks = jax.random.split(KEY, 5)
    b, l, nh, hd, ds = 1, 32, 2, 8, 8
    x = jax.random.normal(ks[0], (b, l, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, nh)))
    a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, l, nh, ds))
    cm = jax.random.normal(ks[4], (b, l, nh, ds))
    y, h = ssd_chunked(x, dt, a, bm, cm, chunk)
    y_ref, h_ref = ref_ssd_scan(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=1e-4, rtol=1e-3)


def test_mamba_valid_len_padding_identity():
    """Right-padding with valid_len masking must not change the state."""
    cfg = get_smoke("mamba2-2.7b")
    pb = ParamBuilder(KEY, jnp.float32)
    init_mamba(pb, cfg)
    p = pb.params
    x = jax.random.normal(KEY, (1, 10, cfg.d_model))
    from repro.models.mamba import init_mamba_cache
    cache = init_mamba_cache(cfg, 1)
    _, (s1, c1) = mamba_layer(p, x, cfg=cfg, cache=cache)
    xp = jnp.pad(x, ((0, 0), (0, 6), (0, 0)))
    _, (s2, c2) = mamba_layer(p, xp, cfg=cfg, cache=cache,
                              valid_len=jnp.array([10]))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2),
                               atol=1e-5, rtol=1e-4)
