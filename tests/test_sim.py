"""End-to-end simulator behaviour: the paper's claims as tests, plus
fault-tolerance (failure re-routing, straggler migration) and the
token-bucket cost model for packed / mixed batches."""
import pytest

from repro.core import (H200_QWEN32B, ControllerConfig, PressureController,
                        Variant, make_policy)
from repro.core.request import Batch, Request
from repro.core.scheduler import PoolPolicy
from repro.core.slo import percentile
from repro.sim import (ClusterSim, H200_32B, SimConfig, closed_loop_clients,
                       lmsys_like_requests)
from repro.sim.workload import WorkloadConfig, length_stats


def run_shared(variant, conc=32, until=40.0, seed=1):
    pol = make_policy(Variant(variant), H200_QWEN32B, threshold=256)
    sim = ClusterSim(1, lambda i: None, H200_32B,
                     SimConfig(router="shared"), shared_policy=pol)
    sim.add_clients(closed_loop_clients(conc, WorkloadConfig(), seed=seed))
    tracker = sim.run(until)
    return tracker


def short_stats(tracker):
    shorts = [r for r in tracker.finished if r.new_tokens < 256]
    tt = [r.ttft() for r in shorts if r.ttft() is not None]
    viol = [r for r in shorts if r.deadline and
            (r.finish_time is None or r.finish_time > r.deadline)]
    return percentile(tt, 0.9), len(viol) / max(len(shorts), 1)


def test_disaggregation_eliminates_short_interference():
    """Paper §4.1: >30% prefill latency reduction for shorts; we see far
    more under mixed closed-loop load."""
    p90_v, viol_v = short_stats(run_shared("vanilla"))
    p90_d, viol_d = short_stats(run_shared("pla_full"))
    assert p90_d < 0.7 * p90_v
    assert viol_d < viol_v


def test_partial_variants_ordering():
    """Fig.6: graphs alone ≈ vanilla; disaggregation carries the win."""
    _, viol_v = short_stats(run_shared("vanilla"))
    _, viol_g = short_stats(run_shared("graph_only"))
    _, viol_d = short_stats(run_shared("disagg_only"))
    assert viol_d < viol_v
    assert abs(viol_g - viol_v) < 0.25


def test_failure_rerouting_completes_all():
    reqs = lmsys_like_requests(300, rate=30.0, seed=3)
    sim = ClusterSim(
        2, lambda i: make_policy(Variant("pla_full"), H200_QWEN32B,
                                 threshold=256),
        H200_32B, SimConfig(router="least_loaded"))
    sim.add_requests(reqs)
    sim.inject_failure(3.0, 0)
    tracker = sim.run(600.0)
    done = {r.rid for r in tracker.finished}
    assert len(done) == len({r.rid for r in reqs})
    # nothing finished on the dead instance after the failure
    late = [r for r in tracker.finished
            if r.instance == 0 and r.finish_time and r.finish_time > 3.0]
    assert not late


def test_spatial_controller_migrates_under_skew():
    model = H200_QWEN32B
    def factory(i):
        # 1 short instance vs 3 long: a short-only flood overloads it
        return PoolPolicy(model, pool="short" if i < 1 else "long",
                          threshold=256)
    ctrl = PressureController(ControllerConfig(t_cool=1.0, tau=0.2,
                                               period=0.5))
    sim = ClusterSim(4, factory, H200_32B,
                     SimConfig(router="pool", control_period=0.5),
                     classifier=lambda r: "short" if r.new_tokens < 256
                     else "long",
                     controller=ctrl)
    sim.add_clients(closed_loop_clients(192, WorkloadConfig(), seed=5,
                                        short_only=True, think_time=0.0))
    sim.run(30.0)
    pools = [getattr(i.policy, "pool", None) for i in sim.instances]
    assert pools.count("short") >= 2, pools
    assert ctrl.history, "controller never ran"


def test_spatial_controller_stable_when_healthy():
    """An idle long pool must NOT strip a busy-but-healthy short pool
    (the utilization credit makes its pressure negative)."""
    model = H200_QWEN32B
    def factory(i):
        return PoolPolicy(model, pool="short" if i < 2 else "long",
                          threshold=256)
    ctrl = PressureController(ControllerConfig(t_cool=1.0, tau=0.2,
                                               period=0.5))
    sim = ClusterSim(4, factory, H200_32B,
                     SimConfig(router="pool", control_period=0.5),
                     classifier=lambda r: "short" if r.new_tokens < 256
                     else "long",
                     controller=ctrl)
    sim.add_clients(closed_loop_clients(16, WorkloadConfig(), seed=5,
                                        short_only=True))
    sim.run(20.0)
    pools = [getattr(i.policy, "pool", None) for i in sim.instances]
    assert pools.count("short") >= 2, pools


def test_straggler_mitigated_by_least_loaded_router():
    reqs = lmsys_like_requests(400, rate=40.0, seed=7)
    def factory(i):
        return make_policy(Variant("pla_full"), H200_QWEN32B, threshold=256)
    sim = ClusterSim(2, factory, H200_32B, SimConfig(router="least_loaded"))
    sim.set_straggler(0, speed=4.0)           # 4× slower instance
    sim.add_requests(reqs)
    tracker = sim.run(600.0)
    by_inst = {0: 0, 1: 0}
    for r in tracker.finished:
        if r.instance in by_inst:
            by_inst[r.instance] += 1
    assert by_inst[1] > 1.5 * by_inst[0]


def test_workload_matches_paper_fig2():
    reqs = lmsys_like_requests(4000, rate=100.0, seed=0)
    stats = length_stats(reqs)
    assert stats["first_lt256"] == pytest.approx(0.63, abs=0.08)
    assert stats["later_lt256"] == pytest.approx(0.81, abs=0.08)


def test_costmodel_packed_prices_bucket_tokens():
    """Packed-vs-grid policy comparisons must price the packed path by
    its REAL token count + bucket tail, not the padded (L, B) shape: the
    acceptance mix (7, 23, 61, 12) pads to 256 tokens on the dense
    (64, 4) graph but runs 103 real + 25 tail tokens in the 128 bucket."""
    reqs = [Request(new_tokens=l) for l in (7, 23, 61, 12)]
    packed = Batch(requests=list(reqs), token_bucket=128, uses_graph=True)
    dense = Batch(requests=list(reqs), bucket_len=64, bucket_depth=4,
                  uses_graph=True)
    assert H200_32B.batch_time(packed) < H200_32B.batch_time(dense)
    # the bucket tail IS priced: the same batch in an oversized bucket
    # costs more (linear-only work on the tail rows)
    oversized = Batch(requests=list(reqs), token_bucket=512, uses_graph=True)
    assert H200_32B.batch_time(oversized) > H200_32B.batch_time(packed)
    # and pricing tracks real tokens, not depth × max-length
    assert H200_32B.packed_batch_time(packed) == \
        H200_32B.batch_time(packed)


def test_costmodel_arena_prefill_drops_slot_copies():
    """§6 pricing parity: the arena-resident packed step bills
    O(history + new) KV rows; the legacy gathered path adds γ_r per
    whole-slot-copy row (2 · b_max · S_max per step) — strictly slower
    for a short-prefill flood, and the modeled HBM bytes/step drop the
    same way the benchmark's acceptance criterion demands (≥ 5×)."""
    from repro.sim.costmodel import packed_hbm_bytes_per_step

    reqs = [Request(new_tokens=l) for l in (7, 5, 9)]
    packed = Batch(requests=list(reqs), token_bucket=64, uses_graph=True)
    rows = 2 * 16 * 256                     # b_max = 16, S_max = 256
    arena_t = H200_32B.packed_batch_time(packed)
    gather_t = H200_32B.packed_batch_time(packed, gather_rows=rows)
    assert gather_t > arena_t
    assert gather_t - arena_t <= H200_32B.gamma_r * rows + 1e-12
    # chunk ticks route the same way
    from repro.core.scheduler import ChunkWork
    w = ChunkWork(req=Request(new_tokens=512), chunk_tokens=64,
                  done_tokens=64, is_last=False, uses_graph=True)
    assert H200_32B.chunk_time(w, gather_rows=rows) > H200_32B.chunk_time(w)
    # the shared bytes formula shows the ≥5× flood-regime reduction
    new, hist = [7, 5, 9], [0, 4, 12]
    a = packed_hbm_bytes_per_step(new, hist, 256, 16, 1.0, arena=True)
    g = packed_hbm_bytes_per_step(new, hist, 256, 16, 1.0, arena=False)
    assert g / a >= 5.0


def test_sim_arena_prefill_routing_matches_engine():
    """The simulator's MIX runs price packed work arena-resident by
    default; flipping SimConfig.arena_prefill=False bills every packed
    tick the whole-slot round-trip — wall-clock strictly grows, nothing
    else changes."""
    def run(arena):
        from repro.core.awd import AWDConfig
        pol = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=256,
                          awd_cfg=AWDConfig(packed=True))
        sim = ClusterSim(1, lambda i: None, H200_32B,
                         SimConfig(mode="mix", arena_prefill=arena,
                                   packed_seqs=16, arena_s_max=256),
                         shared_policy=pol)
        sim.add_clients(closed_loop_clients(8, WorkloadConfig(), seed=3))
        tr = sim.run(20.0)
        return tr.report().n, sim.prefill_rps(20.0)

    n_arena, rps_arena = run(True)
    n_gather, rps_gather = run(False)
    assert n_arena > 0 and n_gather > 0
    assert rps_arena >= rps_gather      # slot copies only ever slow it


def test_costmodel_fused_decode_shares_weight_read():
    """A mixed step's fused decode rows must cost LESS than a separate
    decode step — they ride the prefill dispatch's weight read.  That
    delta is the continuous-batching win the simulator prices."""
    reqs = [Request(new_tokens=l) for l in (7, 23, 12)]
    plain = Batch(requests=list(reqs), token_bucket=64, uses_graph=True)
    mixed = Batch(requests=list(reqs), token_bucket=64, uses_graph=True,
                  decode_tokens=4, kind="mixed")
    extra = H200_32B.batch_time(mixed) - H200_32B.batch_time(plain)
    assert 0 < extra < H200_32B.decode_step_time(4)
    # alternating = packed prefill + separate decode step; fused beats it
    alternating = H200_32B.batch_time(plain) + H200_32B.decode_step_time(4)
    assert H200_32B.batch_time(mixed) < alternating


def test_costmodel_page_walk_pricing():
    """§8 pricing: page_size set bills exactly one page_lookup per
    logical KV block walked — packed steps, chunk ticks, and decode
    buckets all grow by ceil(ctx / page_size) lookups per segment; the
    slot-mapped model (page_size=None) is the zero-walk baseline."""
    import dataclasses as dc
    from repro.core.scheduler import ChunkWork

    paged = dc.replace(H200_32B, page_size=16)
    reqs = [Request(new_tokens=7, history_tokens=121),
            Request(new_tokens=40)]
    b = Batch(requests=list(reqs), token_bucket=64, uses_graph=True)
    blocks = sum(-(-(r.history_tokens + r.new_tokens) // 16)
                 for r in reqs)               # ceil(128/16) + ceil(40/16)
    assert blocks == 11
    assert paged.packed_batch_time(b) == pytest.approx(
        H200_32B.packed_batch_time(b) + paged.page_lookup * blocks)
    w = ChunkWork(req=Request(new_tokens=512), chunk_tokens=64,
                  done_tokens=64, is_last=False, uses_graph=True)
    assert paged.chunk_time(w) == pytest.approx(
        H200_32B.chunk_time(w) + paged.page_lookup * 8)   # ceil(128/16)
    lens = [15, 16, 200]
    walk = sum(-(-(h + 1) // 16) for h in lens)
    assert paged.decode_bucket_time(lens, bucket=4) == pytest.approx(
        H200_32B.decode_bucket_time(lens, bucket=4)
        + paged.page_lookup * walk)
    # prefix hits need NO extra term: matched pages land as history and
    # bill γ_r reads only — strictly cheaper than prefilling them
    hit = Batch(requests=[Request(new_tokens=7, history_tokens=121)],
                token_bucket=64, uses_graph=True)
    cold = Batch(requests=[Request(new_tokens=128)],
                 token_bucket=64, uses_graph=True)
    assert paged.packed_batch_time(hit) < paged.packed_batch_time(cold)


def test_sim_prefix_admission_converts_new_to_history():
    """§8 admission: with prefix_reuse + page_size set, a request's
    annotated reusable_prefix moves page-aligned tokens from new →
    history at add time; ≥ 1 new token always survives; the off switch
    and slot-mapped configs change nothing."""
    def sim_with(**kw):
        return ClusterSim(1, lambda i: make_policy(
            Variant("pla_full"), H200_QWEN32B, threshold=256),
            H200_32B, SimConfig(**kw))

    r = Request(new_tokens=100, reusable_prefix=70, arrival=0.0)
    sim_with(page_size=16, prefix_reuse=True).add_requests([r])
    assert (r.new_tokens, r.history_tokens) == (36, 64)   # 70 → 4 pages
    # exact resubmission: the suffix floor keeps one prefill token
    r = Request(new_tokens=10, reusable_prefix=32, arrival=0.0)
    sim_with(page_size=16, prefix_reuse=True).add_requests([r])
    assert r.new_tokens >= 1 and r.new_tokens + r.history_tokens == 10
    # reuse off, or no paged arena: annotation is inert
    for kw in (dict(page_size=16), dict(prefix_reuse=True)):
        r = Request(new_tokens=100, reusable_prefix=70, arrival=0.0)
        sim_with(**kw).add_requests([r])
        assert (r.new_tokens, r.history_tokens) == (100, 0)


def test_sim_host_spill_admission_and_swap_pricing():
    """§12: host_prefix marks the host-resident part of a reusable
    prefix.  With host_pool_pages == 0 that part is dropped (modeled
    drop-on-evict: it gets re-prefilled); with a pool it stays
    reusable, capped to the pool size, and the promotion is priced via
    CostModel.swap_in_time on the request's first dispatch."""
    def sim_with(**kw):
        return ClusterSim(1, lambda i: make_policy(
            Variant("pla_full"), H200_QWEN32B, threshold=256),
            H200_32B, SimConfig(page_size=16, prefix_reuse=True, **kw))

    # drop-on-evict: the 32 host-resident tokens are re-prefilled
    r = Request(new_tokens=100, reusable_prefix=70, host_prefix=32,
                arrival=0.0)
    sim = sim_with()
    sim.add_requests([r])
    assert (r.new_tokens, r.history_tokens) == (68, 32)   # 38 left → 2 pages
    assert r.swap_time == 0.0 and sim.swapped_pages == 0
    # host pool: the spilled part stays reusable, one swap billed
    r = Request(new_tokens=100, reusable_prefix=70, host_prefix=32,
                arrival=0.0)
    sim = sim_with(host_pool_pages=8)
    sim.add_requests([r])
    assert (r.new_tokens, r.history_tokens) == (36, 64)   # full 70 → 4 pages
    assert sim.swapped_pages == 2                         # 32 host tokens
    assert r.swap_time == pytest.approx(sim.cost.swap_in_time(2 * 16))
    # pool cap: only host_pool_pages·page_size of the host part survives
    r = Request(new_tokens=100, reusable_prefix=70, host_prefix=32,
                arrival=0.0)
    sim_with(host_pool_pages=1).add_requests([r])
    assert (r.new_tokens, r.history_tokens) == (52, 48)
    # pricing shape: zero at zero, monotone in promoted tokens
    assert H200_32B.swap_in_time(0) == 0.0
    assert H200_32B.swap_in_time(32) > H200_32B.swap_in_time(16) > 0.0


def test_sim_multiturn_prefix_reuse_cuts_prefill():
    """Multi-turn trace through the simulator: prefix reuse on a paged
    arena bills strictly fewer prefill tokens and finishes the same
    request set no later than reuse-off."""
    from repro.data.synthetic import MultiTurnConfig, multiturn_requests

    def run(reuse):
        cfg = MultiTurnConfig(vocab_size=1000, num_sessions=16,
                              max_turns=5, seed=4)
        reqs = multiturn_requests(cfg, decode_tokens=4)
        pol = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=256)
        sim = ClusterSim(1, lambda i: None, H200_32B,
                         SimConfig(mode="mix", page_size=16,
                                   prefix_reuse=reuse),
                         shared_policy=pol)
        sim.add_requests(reqs)               # admission mutates in place
        billed = sum(r.new_tokens for r in reqs)
        tr = sim.run(600.0)
        assert len(tr.finished) == len(reqs)
        return billed, max(r.finish_time for r in tr.finished)

    billed_on, makespan_on = run(True)
    billed_off, makespan_off = run(False)
    assert billed_on < billed_off
    assert makespan_on <= makespan_off


def test_mix_mode_reduces_prefill_throughput():
    """Fig.8: co-residing decode lowers prefill RPS."""
    def run(mode):
        pol = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=256)
        sim = ClusterSim(1, lambda i: None, H200_32B,
                         SimConfig(router="shared", mode=mode),
                         shared_policy=pol)
        sim.add_clients(closed_loop_clients(32, WorkloadConfig(), seed=2))
        sim.run(30.0)
        return sim.prefill_rps(30.0)
    assert run("mix") < run("pd")


def test_costmodel_spec_step_amortizes_weight_read():
    """Pricing sanity for §10 speculation: one verify dispatch costs
    more than one decode tick (k extra rows + draft overhead) but FAR
    less than the 1 + accept*k decode ticks it replaces — the weight
    read and the history stream are paid once per dispatch, not once
    per token."""
    cm = H200_32B
    lens = [512, 768]
    k, accept = 4, 0.7
    committed = 1 + round(accept * k)
    spec = cm.spec_step_time(lens, k)
    tick = cm.decode_bucket_time(lens, bucket=len(lens))
    assert spec > tick                      # a dispatch is not free
    assert spec < committed * tick          # but per-token it wins


def test_sim_speculative_drains_decode_backlog_faster():
    """§10 in the simulator: decode-only ticks become verify dispatches
    committing 1 + round(accept*k) tokens per session — a pure decode
    backlog drains in ~(1+round(accept*k))x fewer ticks AND strictly
    less modeled time, because the weight read amortizes across the
    commit.  (Mixed ticks keep plain 1-token pricing — speculation in
    the sim only fires where the multi-commit does.)"""
    def drain(spec):
        pol = make_policy(Variant("pla_full"), H200_QWEN32B,
                          threshold=256)
        sim = ClusterSim(1, lambda i: None, H200_32B,
                         SimConfig(router="shared", mode="mix",
                                   speculative=spec),
                         shared_policy=pol)
        inst = sim.instances[0]
        inst.decode_sessions = [(64, 512 + 64 * i) for i in range(4)]
        t, ticks = 0.0, 0
        while inst.decode_sessions:
            t += sim._decode_tick_time(inst.decode_ctx_lens)
            inst.advance_decodes(sim._spec_commit())
            ticks += 1
        return t, ticks

    t_spec, n_spec = drain(True)
    t_base, n_base = drain(False)
    assert n_spec < n_base
    assert t_spec < t_base
