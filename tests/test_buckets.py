"""§3.1 bucket grid properties."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.buckets import BucketGrid, greedy_length_groups


def grid(budget=16_384):
    return BucketGrid(mem_budget_tokens=budget)


@given(l=st.integers(1, 256))
def test_nearest_length_is_minimal_cover(l):
    g = grid()
    n = g.nearest_length(l)
    assert n is not None and n >= l
    smaller = [x for x in g.lengths if x < n]
    assert all(x < l for x in smaller)


@given(l=st.integers(257, 10_000))
def test_off_grid_lengths_rejected(l):
    assert grid().nearest_length(l) is None


@given(lengths=st.lists(st.integers(1, 256), min_size=1, max_size=64))
def test_nearest_graph_covers(lengths):
    g = grid()
    b = g.nearest_graph(lengths)
    if b is not None:
        assert b.length >= max(lengths)
        assert b.depth >= len(lengths)
        assert b.tokens <= g.mem_budget
        assert 0.0 <= g.padding_waste(lengths) < 1.0


def test_nearest_graph_budget_rejection():
    g = grid(budget=64)
    assert g.nearest_graph([256]) is None     # 256 > 64 budget
    assert g.nearest_graph([8] * 100) is None  # depth 100 off-grid


def test_max_depth():
    g = grid(budget=1024)
    assert g.max_depth(8) == 64
    assert g.max_depth(256) == 4
    assert g.max_depth(256, mem_budget=256) == 1


@given(lengths=st.lists(st.integers(1, 300), min_size=1, max_size=50))
def test_greedy_groups_partition(lengths):
    groups = greedy_length_groups(lengths, grid())
    flat = sorted(i for grp in groups for i in grp)
    assert flat == list(range(len(lengths)))
