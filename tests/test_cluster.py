"""Spatial disaggregation (DESIGN.md §9): router policies over scripted
cluster snapshots, arena→arena KV handoff parity on real engines (slot
AND paged), deflection, and the end-to-end multi-engine ServeCluster."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import H200_QWEN32B
from repro.core.routing import (EngineView, LeastLoadedRouter,
                                LengthAwareRouter, RoundRobinRouter,
                                RouteRequest, make_router)
from repro.core.scheduler import PoolPolicy
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig, ServeCluster
from repro.serving.loop import ServeLoop

KEY = jax.random.key(21)


# ------------------------------------------------------------- router units
def _views(*specs):
    """specs: (role, backlog_tokens[, active_decodes[, queue_len]])"""
    out = []
    for i, s in enumerate(specs):
        role, backlog = s[0], s[1]
        dec = s[2] if len(s) > 2 else 0
        q = s[3] if len(s) > 3 else (1 if backlog else 0)
        out.append(EngineView(engine_id=i, role=role, queue_len=q,
                              backlog_tokens=backlog, active_decodes=dec))
    return out


SHORT = RouteRequest(new_tokens=32)
LONG = RouteRequest(new_tokens=512)


def test_round_robin_cycles():
    r = RoundRobinRouter()
    v = _views(("general", 0), ("general", 0), ("general", 0))
    assert [r.route(SHORT, v) for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_round_robin_skips_dead():
    r = RoundRobinRouter()
    v = _views(("general", 0), ("general", 0), ("general", 0))
    v[1].alive = False
    assert set(r.route(SHORT, v) for _ in range(4)) == {0, 2}


def test_least_loaded_minimizes_backlog():
    r = LeastLoadedRouter()
    v = _views(("general", 90), ("general", 10), ("general", 40))
    assert r.route(SHORT, v) == 1
    v[1].active_decodes = 200        # decode load counts too
    assert r.route(SHORT, v) == 2


def test_least_loaded_tie_breaks_deterministic():
    r = LeastLoadedRouter()
    v = _views(("general", 10, 0, 3), ("general", 10, 0, 1),
               ("general", 10, 0, 1))
    assert r.route(SHORT, v) == 1    # queue_len, then engine id


def test_length_aware_longs_only_on_prefill_engines():
    """The spatial invariant: a long goes to a prefill engine even when
    every prefill engine is busier than every short engine."""
    r = LengthAwareRouter(threshold=256)
    v = _views(("prefill", 900), ("prefill", 700), ("decode", 0),
               ("decode", 0))
    assert r.route(LONG, v) == 1               # least-loaded prefill
    assert r.route(SHORT, v) in (2, 3)         # never the prefill pool


def test_length_aware_threshold_boundary():
    r = LengthAwareRouter(threshold=256)
    v = _views(("prefill", 0), ("decode", 0))
    assert r.route(RouteRequest(new_tokens=256), v) == 0   # >= is long
    assert r.route(RouteRequest(new_tokens=255), v) == 1


def test_length_aware_long_falls_back_without_prefill_pool():
    r = LengthAwareRouter(threshold=256)
    v = _views(("general", 50), ("general", 5))
    assert r.route(LONG, v) == 1


def test_length_aware_spillover_only_to_idle_prefill():
    r = LengthAwareRouter(threshold=256, spill_tokens=64)
    busy_shorts = _views(("prefill", 0), ("decode", 100), ("decode", 80))
    assert r.route(SHORT, busy_shorts) == 0    # shorts drowning → spill
    calm_shorts = _views(("prefill", 0), ("decode", 10), ("decode", 80))
    assert r.route(SHORT, calm_shorts) == 1    # under spill_tokens → stay
    busy_prefill = _views(("prefill", 300), ("decode", 100), ("decode", 80))
    assert r.route(SHORT, busy_prefill) == 2   # prefill not idle → stay


def test_exclude_reroutes_and_never_strands():
    r = LeastLoadedRouter()
    v = _views(("general", 5), ("general", 50))
    assert r.route(SHORT, v, exclude=frozenset({0})) == 1
    # exclusion that empties the eligible set is ignored, not fatal
    assert r.route(SHORT, v, exclude=frozenset({0, 1})) == 0
    v[0].alive = v[1].alive = False
    with pytest.raises(RuntimeError):
        r.route(SHORT, v)


def test_make_router_names():
    assert make_router("rr").name == "round_robin"
    assert make_router("least_loaded").name == "least_loaded"
    assert make_router("spatial", threshold=128).threshold == 128
    with pytest.raises(ValueError):
        make_router("nope")


# ------------------------------------------------------- real-engine fixtures
@pytest.fixture(scope="module")
def smoke():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    return cfg, params


def _ecfg(paged):
    return EngineConfig(num_slots=4, max_len=96, chunk_tokens=16,
                        paged_kv=paged, page_size=8)


def _mk_loop(cfg, params, pool, paged=False):
    eng = Engine(cfg, params, _ecfg(paged))
    pol = PoolPolicy(H200_QWEN32B, pool=pool, threshold=24, chunk_tokens=16)
    return ServeLoop(eng, pol, slo_ttft=30.0)


# ------------------------------------------------------------ handoff parity
@pytest.mark.parametrize("paged", [False, True], ids=["slot", "paged"])
def test_kv_handoff_parity(smoke, paged):
    """Prefill on engine A, export→import into engine B, decode on B:
    tokens identical to the single-engine run and last logits within
    1e-5 — the KV crossed arenas losslessly, without touching host."""
    cfg, params = smoke
    eng_a = Engine(cfg, params, _ecfg(paged))
    eng_b = Engine(cfg, params, _ecfg(paged))
    one = Engine(cfg, params, _ecfg(paged))
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 21)   # partial page on paged

    fa = eng_a.prefill_batch([0], [prompt])
    payload = eng_a.export_session(0)
    eng_b.import_session(0, payload)
    assert eng_b.history(0) == len(prompt)
    db = eng_b.decode_batch([0], [fa[0]], steps=4)

    fo = one.prefill_batch([0], [prompt])
    do = one.decode_batch([0], [fo[0]], steps=4)

    assert fa == fo
    assert db == do
    np.testing.assert_allclose(np.asarray(eng_b.last_logits[0]),
                               np.asarray(one.last_logits[0]), atol=1e-5)
    st = eng_b.stats()
    assert st["handoff_sessions"] == 1
    assert st["handoff_tokens"] == len(prompt)
    assert st["handoff_host_bytes"] == 0
    if paged:
        eng_b.arena.audit()


def test_handoff_source_slot_frees(smoke):
    """After export+close on the source, its slot serves a new session."""
    cfg, params = smoke
    eng_a = Engine(cfg, params, _ecfg(False))
    eng_b = Engine(cfg, params, _ecfg(False))
    rng = np.random.default_rng(7)
    p = rng.integers(0, cfg.vocab_size, 9)
    eng_a.prefill_batch([0], [p])
    eng_b.import_session(0, eng_a.export_session(0))
    eng_a.close_session(0)
    free = eng_a.arena.free_slots
    assert free == eng_a.ecfg.num_slots
    eng_a.prefill_batch([5], [p])           # slot reused cleanly
    assert eng_a.history(5) == 9


# --------------------------------------------------------------- deflection
def test_deflection_bounces_exactly_the_spilled_short(smoke):
    """A short spilled onto an idle prefill engine is withdrawn and
    re-routed (engine excluded) when long work lands behind it; the long
    stays, the short's arrival timestamp survives the detour."""
    cfg, params = smoke
    cluster = ServeCluster(
        [_mk_loop(cfg, params, "long"), _mk_loop(cfg, params, "short")],
        LengthAwareRouter(threshold=24, spill_tokens=0),
        roles=["prefill", "decode"], deflect_backlog_tokens=8)
    rng = np.random.default_rng(8)
    cluster.submit(1, rng.integers(0, cfg.vocab_size, 6))   # decode engine
    spilled = cluster.submit(2, rng.integers(0, cfg.vocab_size, 5))
    assert cluster.engine_of(2) == 0        # spilled onto idle prefill
    assert spilled.rid in cluster._deflectable
    cluster._maybe_deflect()
    assert cluster.deflections == 0         # no long behind it yet
    cluster.submit(3, rng.integers(0, cfg.vocab_size, 40))  # long arrives
    cluster._maybe_deflect()
    assert cluster.deflections == 1
    assert cluster.engine_of(2) == 1        # bounced to the short pool
    assert cluster.engine_of(3) == 0        # the long did NOT move
    lp0, lp1 = cluster.loops
    assert all(p.req.session != 2 for p in lp0._tokens.values())
    re_routed = [p.req for p in lp1._tokens.values() if p.req.session == 2]
    assert len(re_routed) == 1
    assert re_routed[0].arrival == spilled.arrival   # SLO charges the detour
    cluster.run_until_idle(max_wall=180.0)
    assert not cluster.has_work
    assert cluster.report(horizon=1.0).n == 3


# ------------------------------------------------------------- end to end
@pytest.mark.parametrize("paged", [False, True], ids=["slot", "paged"])
def test_cluster_end_to_end_with_migration(smoke, paged):
    """Longs route to the prefill engine, migrate device-to-device after
    prefill, and decode to full budget on the decode engine — transcripts
    complete and no byte of KV bounced through host."""
    cfg, params = smoke
    cluster = ServeCluster(
        [_mk_loop(cfg, params, "long", paged),
         _mk_loop(cfg, params, "short", paged)],
        LengthAwareRouter(threshold=24), roles=["prefill", "decode"],
        migrate_decodes=True)       # force-migrate: budget 3 is below the
    assert cluster.migrate          # §11 cost/benefit gate's breakeven
    rng = np.random.default_rng(9)
    n_tok = {0: 40, 1: 7, 2: 11, 3: 33}     # two longs, two shorts
    for s, n in n_tok.items():
        cluster.submit(s, rng.integers(0, cfg.vocab_size, n),
                       decode_tokens=3)
    assert cluster.engine_of(0) == 0 and cluster.engine_of(3) == 0
    assert cluster.engine_of(1) == 1 and cluster.engine_of(2) == 1
    cluster.run_until_idle(max_wall=300.0)
    assert not cluster.has_work
    for s in n_tok:
        assert len(cluster.generated(s)) == 4, s    # first + 3
    st = cluster.stats()
    assert st["migrated_sessions"] >= 1
    assert st["handoff_sessions"] == st["migrated_sessions"]
    assert st["handoff_host_bytes"] == 0
    # migrated sessions now live on the decode engine
    assert cluster.engine_of(0) == 1 and cluster.engine_of(3) == 1
    assert cluster.report(horizon=1.0).n == 4


def test_cluster_later_turns_pin_to_home(smoke):
    cfg, params = smoke
    cluster = ServeCluster(
        [_mk_loop(cfg, params, "short"), _mk_loop(cfg, params, "short")],
        RoundRobinRouter())
    rng = np.random.default_rng(10)
    cluster.submit(0, rng.integers(0, cfg.vocab_size, 6))
    home = cluster.engine_of(0)
    cluster.run_until_idle(max_wall=120.0)
    cluster.submit(0, rng.integers(0, cfg.vocab_size, 5))
    assert cluster.engine_of(0) == home     # KV lives there
    cluster.run_until_idle(max_wall=120.0)
    assert cluster.loops[home].engine.history(0) == 11


# ------------------------------------------------------------ sim mirror
def test_sim_cluster_decode_handoff():
    """The JAX-free mirror: ClusterSim with a router object and priced
    decode handoff completes every request and fires handoffs from the
    prefill role to the short pool."""
    from repro.sim import ClusterSim, SimConfig
    from repro.sim.costmodel import H200_32B
    from repro.sim.workload import WorkloadConfig, lmsys_like_requests

    wl = WorkloadConfig(slo_ttft=0.4)
    reqs = lmsys_like_requests(120, 30.0, wl, seed=3)
    horizon = reqs[-1].arrival

    def factory(i):
        return PoolPolicy(H200_QWEN32B, pool="long" if i == 0 else "short",
                          threshold=256.0)

    sim = ClusterSim(3, factory, H200_32B,
                     SimConfig(mode="mix", decode_handoff=True),
                     router_obj=LengthAwareRouter(threshold=256.0),
                     roles=["prefill", "decode", "decode"])
    sim.add_requests(reqs)
    tracker = sim.run(horizon + 300)
    assert tracker.report(horizon).n == 120
    assert sim.handoffs > 0
    assert sim.handoff_tokens > 0
