"""Algorithm 2 — instance-pressure controller behaviour."""
import pytest

from repro.core.controller import (ControllerConfig, InstanceStats,
                                   PressureController)


def stats(idx, q=0.0, e=0.0, u=0.0):
    return InstanceStats(idx, q, e, u)


def test_migrates_under_imbalance():
    c = PressureController(ControllerConfig(t_cool=0.0, tau=0.25))
    shorts = [stats(0, q=5.0, e=1.0), stats(1, q=4.0, e=0.8)]
    longs = [stats(2, q=0.1, u=0.2), stats(3, q=0.1, u=0.3)]
    mig = c.step(shorts, longs, now=10.0)
    assert mig is not None
    assert mig.src_pool == "long" and mig.dst_pool == "short"
    assert mig.instance in (2, 3)


def test_respects_n_min():
    c = PressureController(ControllerConfig(t_cool=0.0, n_min=1))
    shorts = [stats(0, q=9.0)]
    longs = [stats(1, q=0.0)]
    assert c.step(shorts, longs, now=1.0) is None  # long pool at n_min


def test_hysteresis_blocks_small_imbalance():
    c = PressureController(ControllerConfig(t_cool=0.0, tau=0.5))
    shorts = [stats(0, q=1.1), stats(1, q=1.0)]
    longs = [stats(2, q=1.0), stats(3, q=0.9)]
    assert c.step(shorts, longs, now=1.0) is None


def test_cooldown():
    c = PressureController(ControllerConfig(t_cool=5.0, tau=0.1))
    shorts = [stats(0, q=9.0), stats(1, q=9.0)]
    longs = [stats(2, q=0.0), stats(3, q=0.0)]
    assert c.step(shorts, longs, now=0.0) is not None
    assert c.step(shorts, longs, now=2.0) is None      # cooling down
    assert c.step(shorts, longs, now=6.0) is not None  # cooled


def test_utilization_credits_pressure():
    c = PressureController(ControllerConfig())
    busy = stats(0, q=1.0, u=1.0)
    idle = stats(1, q=1.0, u=0.0)
    assert c.pressure(busy) < c.pressure(idle)


def test_p90_aggregator_robust_to_one_hot_instance():
    c = PressureController(ControllerConfig(quantile=0.5))
    pool = [stats(i, q=0.1) for i in range(9)] + [stats(9, q=99.0)]
    assert c.pool_pressure(pool) < 1.0     # median ignores the outlier


def test_no_oscillation_on_balanced_load():
    c = PressureController(ControllerConfig(t_cool=0.0, tau=0.25))
    migrations = 0
    for t in range(50):
        shorts = [stats(0, q=1.0 + 0.05 * (t % 2)), stats(1, q=1.0)]
        longs = [stats(2, q=1.0), stats(3, q=1.0 - 0.05 * (t % 2))]
        if c.step(shorts, longs, now=float(t)) is not None:
            migrations += 1
    assert migrations == 0
