"""Paged-by-default acceptance (DESIGN.md §12).

The paged KV arena is now the default for EVERY packed_ok config:
sliding-window stacks serve from ring page tables, hybrid/pure-SSM
stacks step per-session state pages from the same pool.  Proofs here:

  * default-config parity: the paged engine reproduces the slot-arena
    engine (same kernels, different layout) for the windowed and
    hybrid-SSM families at 1e-5 — in Pallas interpret mode too — with
    zero whole-slot gather/scatter and zero dense dispatches;
  * host spill tier: a hypothesis-driven random schedule of submits /
    extends / frees / allocation pressure keeps ``audit()`` green with
    the host pool in play, session-pinned pages never spill, and a
    deterministic device-arena run proves promoted pages come back
    BIT-IDENTICAL to their pre-spill content;
  * chunk-level prefix matching: a long prompt whose prefix lands in
    the radix index while it is being chunk-prefilled adopts the cached
    pages at the next chunk boundary — only the uncached tail is
    billed, and the transcript still matches the cold oracle.
"""
import random

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core import H200_QWEN32B, Variant, make_policy
from repro.kernels import ops as kernel_ops
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig
from repro.serving.kvcache import PagedKVArena
from repro.serving.loop import ServeLoop

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KEY = jax.random.key(12)
TOL = dict(atol=1e-5, rtol=1e-5)


# ------------------------------------------------- default-config parity


def _pair(arch, **kw):
    """(paged default engine, slot-arena oracle) on shared params."""
    cfg = get_smoke(arch)
    params, _ = tr.init_params(cfg, KEY)
    base = dict(num_slots=4, max_len=64, chunk_tokens=16,
                token_buckets=(16, 32, 64), decode_buckets=(1, 2, 4))
    base.update(kw)
    eng = Engine(cfg, params, EngineConfig(**base))
    ora = Engine(cfg, params, EngineConfig(**base, paged_kv=False))
    assert eng._paged and not ora._paged
    return cfg, eng, ora


def _drive_parity(cfg, eng, ora, seed):
    """Mixed prefill + staggered decode + chunked long turn on both
    engines; tokens and logits must agree at 1e-5 at every step."""
    rng = np.random.default_rng(seed)
    t1 = rng.integers(0, cfg.vocab_size, 9)
    t2 = rng.integers(0, cfg.vocab_size, 5)
    r1 = eng.step_mixed([(0, t1), (1, t2)], [])
    r2 = ora.step_mixed([(0, t1), (1, t2)], [])
    assert r1.fused and r2.fused and r1.tokens == r2.tokens
    last = dict(r1.tokens)
    active = [0, 1]
    for i in range(6):
        d1 = eng.decode_batch(active, [last[s] for s in active])
        d2 = ora.decode_batch(active, [last[s] for s in active])
        assert d1 == d2, (i, d1, d2)
        for s in active:
            last[s] = d1[s][0]
            np.testing.assert_allclose(eng.last_logits[s],
                                       ora.last_logits[s], **TOL)
        if i == 3:
            active = [0]
    # chunked long prefill through the packed stream
    long_toks = rng.integers(0, cfg.vocab_size, 40)
    assert eng.prefill_long(2, long_toks) == ora.prefill_long(2, long_toks)
    np.testing.assert_allclose(eng.last_logits[2], ora.last_logits[2],
                               **TOL)
    # §12 acceptance counters on the paged arm
    st_ = eng.stats()
    assert st_["arena_gathers"] == 0 and st_["arena_scatters"] == 0
    assert st_["dense_dispatches"] == 0
    eng.arena.audit()


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "jamba-v0.1-52b",
                                  "mamba2-2.7b"])
def test_paged_default_matches_slot_arena(arch):
    cfg, eng, ora = _pair(arch)
    _drive_parity(cfg, eng, ora, seed=3)
    if arch == "mixtral-8x7b":
        assert eng.arena.ring_pages is not None      # windowed → ring
    else:
        assert eng.arena.state_slots                 # SSM → state pages


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "jamba-v0.1-52b"])
def test_paged_default_parity_interpret_mode(arch):
    """The same parity with the Pallas kernels in interpret mode: ring
    page tables (windowed) and state pages (hybrid) feed the paged
    kernels the exact blocks the slot kernels read."""
    kernel_ops.set_backend("pallas")
    try:
        cfg, eng, ora = _pair(arch)
        _drive_parity(cfg, eng, ora, seed=7)
    finally:
        kernel_ops.set_backend(None)


# ------------------------------------------------------ host spill tier


NUM_PAGES = 8
PS = 4
MAX_LEN = 24
HOST_BUDGET = 6          # bookkeeping mode: _page_bytes == 1


def _write(arena, session, toks):
    h = arena.length(session)
    try:
        arena.prepare_extend(session, len(toks))
    except RuntimeError:
        return False
    arena.commit(session, toks)
    assert arena.length(session) == h + len(toks)
    return True


def _drive_spill(arena, draw_int, draw_choice, steps):
    """Random submit/extend/free schedule under allocation pressure with
    the host tier on.  After every op: audit() green, the host pool
    inside budget, and no live session's pages or tokens perturbed by
    another session's spill/promotion traffic."""
    next_sid = [0]
    transcripts = {}

    def fresh():
        next_sid[0] += 1
        return next_sid[0]

    for _ in range(steps):
        live = sorted(arena._pages)
        snap = {s: (arena.length(s), list(arena.pages_of(s)),
                    list(arena._tokens[s])) for s in live}
        ops = ["submit"] + (["extend", "free"] if live else [])
        op = draw_choice(ops)
        target = None
        if op == "submit":
            # resubmitting a retired conversation exercises promotion;
            # a tiny vocab makes fresh prompts collide with the index
            toks = (list(draw_choice(sorted(transcripts.values(),
                                            key=tuple)))
                    if transcripts and draw_int(0, 1) else [])
            toks += [draw_int(0, 3) for _ in range(draw_int(1, 10))]
            toks = toks[:MAX_LEN - 2]
            target = fresh()
            matched = arena.match_prefix(target, toks)
            assert matched % PS == 0 and matched < len(toks)
            if _write(arena, target, toks[matched:]):
                transcripts[target] = list(toks)
            else:
                arena.free(target)
                target = None
        elif op == "extend":
            target = draw_choice(live)
            ext = [draw_int(0, 3) for _ in range(draw_int(1, 3))]
            if _write(arena, target, ext):
                transcripts[target] = transcripts.get(target, []) + ext
        else:
            target = draw_choice(live)
            arena.free(target)
        arena.audit()
        assert arena.host_pool_pages <= HOST_BUDGET
        # session-pinned pages never spill: every untouched live
        # session keeps its exact page table and committed tokens
        for s, (n, pages, toks_) in snap.items():
            if s == target:
                continue
            assert arena.length(s) == n
            assert arena.pages_of(s) == pages
            assert arena._tokens[s] == toks_
            assert all(arena._refcount[p] >= 1 for p in pages)
    # drain: every page returns to the pool, the host tier stays
    # consistent through the final eviction sweep
    for s in list(arena._pages):
        arena.free(s)
    arena._evict(NUM_PAGES)
    arena.audit()
    assert arena.free_pages == NUM_PAGES


@pytest.mark.parametrize("seed", range(10))
def test_spill_state_machine_seeded(seed):
    rng = random.Random(seed)
    arena = PagedKVArena(None, NUM_PAGES, PS, MAX_LEN,
                         host_pool_bytes=HOST_BUDGET)
    _drive_spill(arena, rng.randint, rng.choice, steps=50)


def test_spill_pressure_actually_spills():
    """The seeded machine is only a proof if the spill path fires: a
    deterministic pressure schedule must demote AND promote."""
    rng = random.Random(1234)
    arena = PagedKVArena(None, NUM_PAGES, PS, MAX_LEN,
                         host_pool_bytes=HOST_BUDGET)
    _drive_spill(arena, rng.randint, rng.choice, steps=120)
    assert arena.pages_spilled > 0
    assert arena.pages_promoted > 0


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.data())
    def test_spill_state_machine_hypothesis(data):
        arena = PagedKVArena(None, NUM_PAGES, PS, MAX_LEN,
                             host_pool_bytes=HOST_BUDGET)
        _drive_spill(arena,
                     lambda lo, hi: data.draw(st.integers(lo, hi)),
                     lambda seq: data.draw(st.sampled_from(list(seq))),
                     steps=data.draw(st.integers(5, 40), label="steps"))
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_spill_state_machine_hypothesis():
        pass


def test_promoted_pages_bit_identical():
    """Device arena: pages demoted to the host tier and promoted back
    on a prefix match carry EXACTLY the bytes they held before the
    spill."""
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(21)
    eng = Engine(cfg, params, EngineConfig(
        num_slots=2, max_len=64, page_size=8, num_pages=8,
        chunk_tokens=16, token_buckets=(16, 32), decode_buckets=(1, 2),
        host_pool_bytes=256 << 20))
    ar = eng.arena
    toks = [int(t) for t in rng.integers(0, cfg.vocab_size, 25)]
    eng.prefill_batch([0], [np.asarray(toks)])      # 3 full pages + tail
    full_pages = list(ar.pages_of(0))[:3]
    snap = [jax.tree.map(lambda a, p=p: np.asarray(a[:, p]), ar.arena)
            for p in full_pages]
    eng.close_session(0)                            # pages live on index
    # allocation pressure: two throwaway sessions exhaust the 8-page
    # pool, forcing the index-only pages through the spill path
    eng.prefill_long(1, rng.integers(0, cfg.vocab_size, 40))   # 5 pages
    eng.prefill_batch([2], [rng.integers(0, cfg.vocab_size, 24)])
    assert ar.pages_spilled >= 3
    eng.close_session(1)
    eng.close_session(2)
    # a resubmission promotes the spilled prefix back to device pages
    matched = ar.match_prefix(9, toks)
    assert matched == 24 and ar.pages_promoted >= 3
    for want, p in zip(snap, ar.pages_of(9)):
        got = jax.tree.map(lambda a, p=p: np.asarray(a[:, p]), ar.arena)
        for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
            np.testing.assert_array_equal(w, g)
    ar.audit()


# ------------------------------------------- chunk-level prefix matching


def _paged_loop(cfg, params, chunk_matching=True):
    eng = Engine(cfg, params, EngineConfig(
        num_slots=6, max_len=128, page_size=8, chunk_tokens=16,
        token_buckets=(16, 32), decode_buckets=(1, 2, 4)))
    pol = make_policy(Variant("pla_full"), H200_QWEN32B, threshold=32,
                      chunk_tokens=16)
    loop = ServeLoop(eng, pol, slo_ttft=30.0)
    loop.chunk_matching = chunk_matching
    return eng, loop


def test_chunk_matching_bills_only_uncached_tail():
    """Two long prompts sharing a 48-token prefix submitted together,
    both COLD: the first chunk-prefills the prefix into the index, the
    second adopts it at its next chunk boundary — its billed prefill
    shrinks to the uncached tail, and both transcripts still match the
    slot-arena oracle."""
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    rng = np.random.default_rng(33)
    shared = rng.integers(0, cfg.vocab_size, 48)
    tails = [rng.integers(0, cfg.vocab_size, 16) for _ in range(2)]
    prompts = [np.concatenate([shared, t]) for t in tails]

    results = {}
    for matching in (True, False):
        eng, loop = _paged_loop(cfg, params, chunk_matching=matching)
        for s, p in enumerate(prompts):
            loop.submit(s, p, decode_tokens=3)
        loop.run_until_idle(max_wall=120.0)
        st_ = eng.stats()
        results[matching] = (st_["packed_useful_tokens"],
                             st_["chunk_hit_tokens"],
                             {s: list(loop.generated[s]) for s in (0, 1)})
        assert st_["arena_gathers"] == 0 and st_["arena_scatters"] == 0
        eng.arena.audit()
    useful_on, chunk_on, gen_on = results[True]
    useful_off, chunk_off, gen_off = results[False]
    # the adopted chunks disappear from the billed prefill stream (at
    # least two full chunks' worth — the exact count depends on how the
    # two requests' chunk boundaries interleave)
    assert chunk_on >= 32 and chunk_off == 0
    assert useful_on <= useful_off - 32
    # losslessness: the transcripts do not depend on the adoption
    assert gen_on == gen_off
    # oracle parity for the adopting request: same greedy stream as a
    # dedicated slot-arena engine prefilling the whole prompt cold
    ora = Engine(cfg, params, EngineConfig(num_slots=4, max_len=128,
                                           paged_kv=False))
    tok = ora.prefill_batch([1], [prompts[1]])[1]
    stream = [tok]
    for _ in range(3):
        tok = ora.decode_batch([1], [tok])[1][0]
        stream.append(tok)
    assert gen_on[1] == stream
