"""Arena-resident bucketed decode (DESIGN.md §5): kernel-level parity of
the slot-map flash-decode against the dense oracle (GQA/MHA/MQA, ragged
cache lengths incl. cached_len == S_max), engine-level parity of the
bucketed path vs the dense gather/scatter oracle (logits + KV to 1e-5,
interpret mode included), decode-ladder / pad-row invariants, and the
per-session sampling options riding the same logits gather."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.buckets import DecodeBucketLadder
from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.kernels.decode_attn import decode_attn_arena
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.packing import pad_decode_rows
from repro.serving.sampling import make_rng, sample_token

KEY = jax.random.key(21)
TOL = dict(atol=1e-5, rtol=0)
TOL_INTERPRET = dict(atol=2e-5, rtol=0)


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


# ----------------------------------------------------------- kernel level


@pytest.mark.parametrize("b,nslots,s,hq,hkv,d,bk", [
    (3, 8, 64, 8, 2, 32, 16),     # GQA
    (2, 5, 100, 4, 4, 64, 32),    # MHA, S not a multiple of block_k
    (4, 6, 32, 8, 1, 16, 32),     # MQA
])
def test_arena_kernel_matches_oracle(b, nslots, s, hq, hkv, d, bk):
    ks = jax.random.split(KEY, 5)
    q = rand(ks[0], (b, hq, d))
    k = rand(ks[1], (nslots, s, hkv, d))
    v = rand(ks[2], (nslots, s, hkv, d))
    slot = jax.random.permutation(ks[3], nslots)[:b]
    lens = jax.random.randint(ks[4], (b,), 1, s + 1)
    out = decode_attn_arena(q, k, v, slot, lens, block_k=bk)
    want = ref.ref_decode_attn_arena(q, k, v, slot, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_arena_kernel_full_cache():
    """cached_len == S_max: the deepest session still reads every valid
    block and nothing past the arena edge."""
    ks = jax.random.split(KEY, 4)
    b, nslots, s, hq, hkv, d = 2, 4, 48, 4, 2, 32
    q = rand(ks[0], (b, hq, d))
    k = rand(ks[1], (nslots, s, hkv, d))
    v = rand(ks[2], (nslots, s, hkv, d))
    slot = jnp.array([3, 0], jnp.int32)
    lens = jnp.array([s, 1], jnp.int32)
    out = decode_attn_arena(q, k, v, slot, lens, block_k=16)
    want = ref.ref_decode_attn_arena(q, k, v, slot, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ----------------------------------------------------------- engine level

CONFIGS = {
    "qwen3-4b": lambda: get_smoke("qwen3-4b"),
    "mha": lambda: get_smoke("qwen3-4b").replace(name="mha-smoke",
                                                 num_kv_heads=4),
}


def pair(cfg):
    """(bucketed-decode engine, dense-gather oracle) on shared params."""
    params, _ = tr.init_params(cfg, KEY)
    eng = Engine(cfg, params, EngineConfig(num_slots=8, max_len=64,
                                           decode_buckets=(1, 2, 4),
                                           paged_kv=False))
    ora = Engine(cfg, params, EngineConfig(num_slots=8, max_len=64,
                                           arena_decode=False,
                                           paged_kv=False))
    return eng, ora


def assert_kv_parity(eng, ora, sessions, tol=TOL):
    for s in sessions:
        n = eng.arena.length(s)
        assert n == ora.arena.length(s), (s, n, ora.arena.length(s))
        sm, so = eng.arena.slot_of(s), ora.arena.slot_of(s)
        for cm, co in zip(eng.arena.arena, ora.arena.arena):
            for part in ("k", "v"):
                np.testing.assert_allclose(
                    np.asarray(cm[part][:, sm, :n]),
                    np.asarray(co[part][:, so, :n]),
                    err_msg=f"session {s} cache {part}", **tol)


@pytest.mark.parametrize("arch", list(CONFIGS))
def test_decode_bucket_parity(arch):
    """Bucketed arena decode over ragged cached lengths == the dense
    gather/scatter oracle, token for token, while the live session count
    shrinks across ladder rungs."""
    cfg = CONFIGS[arch]()
    rng = np.random.default_rng(31)
    eng, ora = pair(cfg)
    lens = [5, 12, 23]
    prompts = [rng.integers(0, cfg.vocab_size, l) for l in lens]
    f1 = eng.prefill_batch([0, 1, 2], prompts)
    f2 = ora.prefill_batch([0, 1, 2], prompts)
    assert f1 == f2
    last1, last2 = dict(f1), dict(f2)
    for active in ([0, 1, 2], [0, 1, 2], [2, 0], [0]):   # shrinking set
        d1 = eng.decode_batch(active, [last1[s] for s in active])
        d2 = ora.decode_batch(active, [last2[s] for s in active])
        assert d1 == d2
        for s in active:
            last1[s], last2[s] = d1[s][0], d2[s][0]
            np.testing.assert_allclose(eng.last_logits[s],
                                       ora.last_logits[s],
                                       err_msg=f"session {s} logits", **TOL)
    assert_kv_parity(eng, ora, (0, 1, 2))
    # the dense decode path was never touched on the bucketed engine
    assert eng.executor.shapes_by_kind().get("decode", 0) == 0
    assert eng.decode_executor.dispatches == 4


def test_decode_bucket_deep_cache_parity():
    """A session one row short of the arena edge (the parked junk row)
    still decodes in place correctly."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(37)
    params, _ = tr.init_params(cfg, KEY)
    eng = Engine(cfg, params, EngineConfig(num_slots=4, max_len=32,
                                           decode_buckets=(1, 2),
                                           paged_kv=False))
    ora = Engine(cfg, params, EngineConfig(num_slots=4, max_len=32,
                                           arena_decode=False,
                                           paged_kv=False))
    toks = rng.integers(0, cfg.vocab_size, 29)
    f1 = eng.prefill_batch([0], [toks])
    f2 = ora.prefill_batch([0], [toks])
    assert f1 == f2
    assert eng.decode_batch([0], [f1[0]]) == ora.decode_batch([0], [f2[0]])
    assert eng.arena.length(0) == 30                     # max_len - 2
    assert_kv_parity(eng, ora, (0,))


def test_decode_bucket_parity_interpret_mode():
    """Same parity with the Pallas kernel in interpret mode: the slot-map
    index maps and the length-clamped block fetches match the oracle."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(41)
    kernel_ops.set_backend("pallas")
    try:
        eng, ora = pair(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, l) for l in (7, 18)]
        f1 = eng.prefill_batch([0, 1], prompts)
        f2 = ora.prefill_batch([0, 1], prompts)
        d1 = eng.decode_batch([0, 1], [f1[0], f1[1]], steps=2)
        d2 = ora.decode_batch([0, 1], [f2[0], f2[1]], steps=2)
        assert d1 == d2
        for s in (0, 1):
            np.testing.assert_allclose(eng.last_logits[s],
                                       ora.last_logits[s], **TOL_INTERPRET)
        assert_kv_parity(eng, ora, (0, 1), tol=TOL_INTERPRET)
    finally:
        kernel_ops.set_backend(None)


def test_decode_ladder_tops_out_at_arena_depth_in_engine():
    """A configured ladder stopping short of the arena depth is topped
    by the arena depth itself, so a full-arena tick still runs the
    bucketed path — never the dense per-count fallback."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(43)
    params, _ = tr.init_params(cfg, KEY)
    eng = Engine(cfg, params, EngineConfig(num_slots=8, max_len=64,
                                           decode_buckets=(1, 2),
                                           paged_kv=False))
    assert eng.decode_executor.decode_buckets == (1, 2, 8)
    prompts = [rng.integers(0, cfg.vocab_size, 5) for _ in range(3)]
    f = eng.prefill_batch([0, 1, 2], prompts)
    d = eng.decode_batch([0, 1, 2], [f[s] for s in (0, 1, 2)])
    assert set(d) == {0, 1, 2}
    assert eng.decode_executor.dispatches == 1           # 3 → top rung 8
    assert eng.executor.shapes_by_kind().get("decode", 0) == 0


# ------------------------------------------------------- ladder / padding


def test_decode_ladder_caps_at_arena_depth():
    lad = DecodeBucketLadder((1, 2, 4, 8, 16, 32), max_seqs=6)
    assert lad.buckets == (1, 2, 4, 6)
    assert lad.bucket_for(5) == 6
    assert lad.bucket_for(7) is None
    assert DecodeBucketLadder((1, 2, 4)).bucket_for(3) == 4
    # deep arenas get the arena depth as a top rung too
    deep = DecodeBucketLadder((1, 2, 4, 8, 16, 32), max_seqs=64)
    assert deep.buckets == (1, 2, 4, 8, 16, 32, 64)
    assert deep.bucket_for(40) == 64


def test_decode_pad_rows_counters():
    """ExecutorStats track the decode bucket's pad rows (note_padding
    fires on the decode path) and report per-kind hit rates."""
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(47)
    params, _ = tr.init_params(cfg, KEY)
    # packed=False pins prefill to the dense executor so its per-kind
    # hit rates stay observable next to the bucketed decode counters
    eng = Engine(cfg, params, EngineConfig(num_slots=8, max_len=64,
                                           packed=False, paged_kv=False,
                                           decode_buckets=(1, 2, 4)))
    f = eng.prefill_batch([0, 1, 2], [rng.integers(0, cfg.vocab_size, 4)
                                      for _ in range(3)])
    eng.decode_batch([0, 1, 2], [f[s] for s in (0, 1, 2)])   # 3 → bucket 4
    dx = eng.decode_executor
    assert dx.useful_tokens == 3 and dx.total_tokens == 4
    assert dx.padded_tokens == 1
    st = eng.stats()
    assert st["decode_pad_rows"] == 1
    assert st["decode_shapes"] == 1
    assert "arena_decode" in dx.hit_rate_by_kind
    assert "prefill" in st["hit_rate_by_kind"]


# -------------------------------------------------------------- sampling


def test_sampling_greedy_default_matches_argmax():
    logits = np.array([0.1, 2.0, -1.0, 0.5])
    assert sample_token(logits, SamplingParams()) == 1


def test_sampling_temperature_topk_support():
    rng = make_rng(0, SamplingParams(temperature=1.0, top_k=2, seed=9))
    logits = np.array([5.0, 4.0, -50.0, -60.0])
    draws = {sample_token(logits, SamplingParams(temperature=1.0, top_k=2,
                                                 seed=9), rng)
             for _ in range(50)}
    assert draws <= {0, 1} and len(draws) == 2     # top-k truncates support


def test_sampling_seeded_reproducible_in_engine():
    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(53)
    params, _ = tr.init_params(cfg, KEY)
    toks = rng.integers(0, cfg.vocab_size, 6)
    runs = []
    for _ in range(2):
        eng = Engine(cfg, params, EngineConfig(num_slots=4, max_len=64,
                                               decode_buckets=(1, 2)))
        eng.open_session(0)
        eng.set_sampling(0, SamplingParams(temperature=0.9, top_k=8,
                                           seed=123))
        f = eng.prefill_batch([0], [toks])
        runs.append(eng.decode_batch([0], [f[0]], steps=4)[0])
    assert runs[0] == runs[1]                      # same seed, same stream


def test_sampling_top_p_truncates_support():
    """Nucleus sampling: with one dominant token, top_p below its prob
    mass keeps only that token; larger top_p widens the support."""
    logits = np.array([4.0, 3.0, 2.9, -50.0])
    rng = make_rng(0, SamplingParams(seed=1))
    tight = {sample_token(logits, SamplingParams(temperature=1.0,
                                                 top_p=0.5), rng)
             for _ in range(50)}
    assert tight == {0}                    # p(0) ≈ 0.66 covers 0.5 alone
    rng = make_rng(0, SamplingParams(seed=2))
    flat = np.array([2.0, 1.9, 1.8, -50.0])
    wide = {sample_token(flat, SamplingParams(temperature=1.0,
                                              top_p=0.999), rng)
            for _ in range(200)}
    assert wide == {0, 1, 2}               # tail token stays excluded


def test_sampling_logit_bias_applies_even_when_greedy():
    """Logit bias lands before EVERY draw — including greedy argmax —
    so a banned token never surfaces and a boosted one can win."""
    logits = np.array([0.1, 2.0, -1.0, 0.5])
    assert sample_token(logits, SamplingParams()) == 1
    ban = SamplingParams(logit_bias={1: -100.0})
    assert not ban.is_default and ban.is_greedy
    assert sample_token(logits, ban) == 3
    boost = SamplingParams(logit_bias={2: +100.0})
    assert sample_token(logits, boost) == 2
    # and under temperature sampling the banned token never appears
    rng = make_rng(0, SamplingParams(seed=5))
    draws = {sample_token(logits, SamplingParams(temperature=1.0,
                                                 logit_bias={1: -1e9},
                                                 seed=5), rng)
             for _ in range(50)}
    assert 1 not in draws


def test_sampling_top_p_bias_replayable_through_serve_loop():
    """Satellite acceptance: top-p + logit-bias options threaded through
    ServeLoop.submit produce a REPLAYABLE stream — two identical runs,
    one generated transcript — and the bias holds on every token."""
    from repro.core import H200_QWEN32B, Variant, make_policy
    from repro.serving.loop import ServeLoop

    cfg = CONFIGS["qwen3-4b"]()
    rng = np.random.default_rng(59)
    params, _ = tr.init_params(cfg, KEY)
    toks = rng.integers(0, cfg.vocab_size, 7)
    banned = 3
    sp = SamplingParams(temperature=0.8, top_k=16, top_p=0.9, seed=71,
                        logit_bias={banned: -1e9})
    runs = []
    for _ in range(2):
        eng = Engine(cfg, params, EngineConfig(num_slots=4, max_len=64,
                                               packed=True,
                                               token_buckets=(64, 128)))
        loop = ServeLoop(eng, make_policy(Variant("pla_full"),
                                          H200_QWEN32B, threshold=32),
                         slo_ttft=30.0)
        loop.submit(0, toks, decode_tokens=5, sampling=sp)
        loop.run_until_idle(max_wall=120.0)
        runs.append(list(loop.generated[0]))
    assert runs[0] == runs[1] and len(runs[0]) == 6
    assert banned not in runs[0]
