"""Training substrate: convergence, checkpoint/restart determinism,
optimizer behaviour."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_smoke
from repro.data import SyntheticConfig, SyntheticLM
from repro.models import transformer as tr
from repro.optim import AdamWConfig, adamw_init, adamw_update, lr_schedule
from repro.training import TrainConfig, TrainLoop


def test_loss_decreases():
    cfg = get_smoke("qwen3-4b")
    dcfg = SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=32, batch=8,
                           accum=1, seed=7)
    loop = TrainLoop(cfg, AdamWConfig(lr=1e-3, warmup_steps=3, total_steps=40),
                     SyntheticLM(dcfg), TrainConfig(steps=20, log_every=5))
    loop.run(jax.random.key(0))
    losses = [h["loss"] for h in loop.history]
    assert losses[-1] < losses[0] - 0.3


def test_checkpoint_restart_exact():
    """Continuous run and killed-and-restarted run reach identical state."""
    cfg = get_smoke("stablelm-1.6b")
    dcfg = SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=16, batch=4,
                           accum=1, seed=3)
    ocfg = AdamWConfig(lr=5e-4, warmup_steps=2, total_steps=20)

    with tempfile.TemporaryDirectory() as d:
        # continuous 10 steps
        l1 = TrainLoop(cfg, ocfg, SyntheticLM(dcfg),
                       TrainConfig(steps=10, log_every=100))
        p_cont, _ = l1.run(jax.random.key(1))
        # 5 steps, checkpoint, "crash", resume to 10
        l2 = TrainLoop(cfg, ocfg, SyntheticLM(dcfg),
                       TrainConfig(steps=5, ckpt_dir=d, ckpt_every=5,
                                   log_every=100))
        l2.run(jax.random.key(1))
        l3 = TrainLoop(cfg, ocfg, SyntheticLM(dcfg),
                       TrainConfig(steps=10, ckpt_dir=d, ckpt_every=100,
                                   log_every=100))
        p_resumed, _ = l3.run(jax.random.key(1))
    for a, b in zip(jax.tree.leaves(p_cont), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


def test_checkpoint_roundtrip_and_latest():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, jax.random.key(0))
    opt = adamw_init(params)
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(d, 3, params, opt, {"x": 1})
        save_checkpoint(d, 7, params, opt)
        assert latest_step(d) == 7
        p2, o2, meta = load_checkpoint(d, 3, params, opt)
        assert meta["step"] == 3 and meta["x"] == 1
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_clip_and_schedule():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=10, total_steps=100,
                      grad_clip=1.0)
    # warmup is linear
    assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(5e-3)
    # decays to min ratio
    end = float(lr_schedule(cfg, jnp.asarray(100)))
    assert end == pytest.approx(cfg.lr * cfg.min_lr_ratio, rel=1e-3)
    # huge grads get clipped: update magnitude bounded
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 1e6)}
    p2, _, m = adamw_update(g, state, cfg)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 0.1


def test_data_pipeline_deterministic_and_seekable():
    c = SyntheticConfig(vocab_size=100, seq_len=16, batch=2, accum=2, seed=5)
    a = SyntheticLM(c)
    b1 = a.next_batch()
    b2 = a.next_batch()
    # restore to step 1 and re-read
    b = SyntheticLM(c)
    b.restore({"step": 1})
    b2r = b.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 2, 16)
    # labels are next-token shifted
    assert (b1["labels"][:, :, :-1] == b1["tokens"][:, :, 1:]).mean() > 0.99
