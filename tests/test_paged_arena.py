"""Paged KV arena (DESIGN.md §8): kernel-level parity of the page-table
ragged prefill / decode against the gathered-page oracle on fragmented,
shared, and COW-forked page layouts (GQA/MHA/MQA, interpret mode),
engine-level parity of the paged engine vs the slot-arena engine (logits
to 1e-5 on prefill, mixed, and bucketed decode ticks), radix prefix
reuse producing logits identical to a cold prefill while billing only
the new suffix, the COW-fork regression (satellite of §8: forked
branches match independently prefilled sessions through decode across
page boundaries), and page hygiene — pad rows only ever touch the
reserved scratch page.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.kernels import ops as kernel_ops
from repro.kernels import ref
from repro.kernels.decode_attn import decode_attn_paged
from repro.kernels.ragged_prefill import ragged_prefill_paged
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig

KEY = jax.random.key(41)
TOL = dict(atol=1e-5, rtol=0)
TOL_INTERPRET = dict(atol=2e-5, rtol=0)


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def make_stream(lens, hists):
    b = len(lens)
    cu = np.zeros(b + 1, np.int32)
    cu[1:] = np.cumsum(lens)
    off = np.asarray(hists, np.int32)
    kvl = off + np.asarray(lens, np.int32)
    return jnp.asarray(cu), jnp.asarray(off), jnp.asarray(kvl)


def page_layout(rng, npages, ps, p_max, lens, hists, share=None):
    """A fragmented page table: each segment gets ceil((h+l)/ps) DISTINCT
    random pages; ``share=(a, b, k)`` makes segment b reuse segment a's
    first k pages (prefix sharing / COW fork layouts).  Unused table
    entries point at page 0 (always in range; masked by kv_lengths)."""
    table = np.zeros((len(lens), p_max), np.int32)
    free = list(rng.permutation(npages))
    for i, (l, h) in enumerate(zip(lens, hists)):
        need = -(-(h + l) // ps)
        table[i, :need] = [free.pop() for _ in range(need)]
    if share is not None:
        a, b, k = share
        table[b, :k] = table[a, :k]
    return table


# ----------------------------------------------------------- kernel level


@pytest.mark.parametrize("npages,ps,hq,hkv,d,bq", [
    (24, 16, 8, 2, 32, 16),    # GQA
    (16, 8, 4, 4, 64, 8),      # MHA
    (20, 16, 8, 1, 16, 8),     # MQA
])
def test_paged_prefill_kernel_matches_oracle(npages, ps, hq, hkv, d, bq):
    """Fragmented + prefix-shared page layout: the page-table index map
    reads exactly the gathered pages the oracle sees."""
    ks = jax.random.split(KEY, 3)
    rng = np.random.default_rng(npages)
    lens = [5, 9, 4]
    hists = [7, 0, 12]
    p_max = 4
    t = sum(lens) + 3                          # bucket tail rows
    q = rand(ks[0], (t, hq, d))
    k = rand(ks[1], (npages, ps, hkv, d))
    v = rand(ks[2], (npages, ps, hkv, d))
    # segments 0 and 2 share their first page — radix prefix reuse
    table = page_layout(rng, npages, ps, p_max, lens, hists,
                        share=(0, 2, 1))
    cu, off, kvl = make_stream(lens, hists)
    out = ragged_prefill_paged(q, k, v, jnp.asarray(table), cu, off, kvl,
                               block_q=bq)
    want = ref.ref_ragged_prefill_paged(q, k, v, jnp.asarray(table), cu,
                                        q_offsets=off, kv_lengths=kvl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)
    np.testing.assert_array_equal(np.asarray(out)[sum(lens):], 0.0)


def test_paged_prefill_kernel_full_table():
    """history + new fills every page of the table: the last logical
    block is read fully and nothing past the table is touched."""
    ks = jax.random.split(KEY, 3)
    npages, ps, p_max, hq, hkv, d = 12, 8, 4, 4, 2, 16
    lens, hists = [6, 4], [ps * p_max - 6, 0]
    t = sum(lens)
    q = rand(ks[0], (t, hq, d))
    k = rand(ks[1], (npages, ps, hkv, d))
    v = rand(ks[2], (npages, ps, hkv, d))
    table = page_layout(np.random.default_rng(3), npages, ps, p_max,
                        lens, hists)
    cu, off, kvl = make_stream(lens, hists)
    assert int(kvl[0]) == ps * p_max
    out = ragged_prefill_paged(q, k, v, jnp.asarray(table), cu, off, kvl,
                               block_q=8)
    want = ref.ref_ragged_prefill_paged(q, k, v, jnp.asarray(table), cu,
                                        q_offsets=off, kv_lengths=kvl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("hq,hkv", [(8, 2), (4, 4), (8, 1)])
def test_paged_decode_kernel_matches_oracle(hq, hkv):
    """COW-forked decode layout: two rows share every prefix page and
    diverge only on their (copied) boundary page."""
    ks = jax.random.split(KEY, 3)
    npages, ps, p_max, d, b = 20, 16, 4, 32, 4
    rng = np.random.default_rng(5)
    lengths = np.asarray([37, 37, 9, 51], np.int32)
    table = page_layout(rng, npages, ps, p_max,
                        list(lengths), [0] * b)
    # rows 0/1: a fork — shared full pages, distinct boundary pages
    table[1, :2] = table[0, :2]
    assert table[1, 2] != table[0, 2]
    q = rand(ks[0], (b, hq, d))
    k = rand(ks[1], (npages, ps, hkv, d))
    v = rand(ks[2], (npages, ps, hkv, d))
    out = decode_attn_paged(q, k, v, jnp.asarray(table),
                            jnp.asarray(lengths))
    want = ref.ref_decode_attn_paged(q, k, v, jnp.asarray(table),
                                     jnp.asarray(lengths))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_ops_dispatch_paged_backends_agree():
    """ops.ragged_mha_paged / ops.decode_paged: forced-pallas (interpret)
    and forced-ref return the same values."""
    ks = jax.random.split(KEY, 3)
    npages, ps, p_max, hq, hkv, d = 12, 8, 3, 4, 2, 16
    lens, hists = [5, 3], [6, 0]
    q = rand(ks[0], (sum(lens) + 2, hq, d))
    k = rand(ks[1], (npages, ps, hkv, d))
    v = rand(ks[2], (npages, ps, hkv, d))
    table = jnp.asarray(page_layout(np.random.default_rng(1), npages, ps,
                                    p_max, lens, hists))
    cu, off, kvl = make_stream(lens, hists)
    qd = rand(ks[0], (2, hq, d))
    lengths = jnp.asarray([11, 7], jnp.int32)
    try:
        kernel_ops.set_backend("pallas")
        a1 = kernel_ops.ragged_mha_paged(q, k, v, table, cu, off, kvl)
        d1 = kernel_ops.decode_paged(qd, k, v, table[:2], lengths)
        kernel_ops.set_backend("ref")
        a2 = kernel_ops.ragged_mha_paged(q, k, v, table, cu, off, kvl)
        d2 = kernel_ops.decode_paged(qd, k, v, table[:2], lengths)
    finally:
        kernel_ops.set_backend(None)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               **TOL_INTERPRET)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               **TOL_INTERPRET)


# ----------------------------------------------------------- engine level


@pytest.fixture(scope="module")
def stack():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    return cfg, params


def build_pair(cfg, params, **paged_kw):
    kw = dict(num_slots=8, max_len=128, chunk_tokens=32, packed=True,
              token_buckets=(64, 128, 256))
    eng = Engine(cfg, params, EngineConfig(**kw, paged_kv=True,
                                           page_size=16, **paged_kw))
    ora = Engine(cfg, params, EngineConfig(**kw, paged_kv=False))
    return eng, ora


def test_paged_engine_matches_slot_engine(stack):
    """Prefill batch, fused mixed tick, and bucketed decode on the paged
    engine reproduce the slot-arena engine logits token for token, with
    zero whole-slot gather/scatter."""
    cfg, params = stack
    eng, ora = build_pair(cfg, params)
    rng = np.random.default_rng(0)
    t1, t2 = (rng.integers(0, cfg.vocab_size, n) for n in (21, 13))
    r1 = eng.step_mixed([(1, t1), (2, t2)], [])
    r2 = ora.step_mixed([(1, t1), (2, t2)], [])
    assert r1.fused and r2.fused and r1.tokens == r2.tokens
    # fused decode rows + a fresh prefill in one tick
    t3 = rng.integers(0, cfg.vocab_size, 9)
    r1 = eng.step_mixed([(3, t3)], [(1, r1.tokens[1]), (2, r1.tokens[2])])
    r2 = ora.step_mixed([(3, t3)], [(1, r2.tokens[1]), (2, r2.tokens[2])])
    assert r1.tokens == r2.tokens
    # bucketed decode ticks
    d1 = eng.decode_batch([1, 2, 3], [r1.tokens[s] for s in (1, 2, 3)],
                          steps=4)
    d2 = ora.decode_batch([1, 2, 3], [r2.tokens[s] for s in (1, 2, 3)],
                          steps=4)
    assert d1 == d2
    for s in (1, 2, 3):
        np.testing.assert_allclose(eng.last_logits[s], ora.last_logits[s],
                                   **TOL)
    st = eng.stats()
    assert st["arena_gathers"] == 0 and st["arena_scatters"] == 0
    assert st["dense_dispatches"] == 0
    eng.arena.audit()


def test_prefix_reuse_matches_cold_prefill(stack):
    """Turn 2 resubmits the full conversation under a fresh session: the
    radix index maps the matched prefix onto turn 1's pages, ONLY the
    suffix is prefilled, and the logits equal a cold prefill of the
    whole conversation to 1e-5."""
    cfg, params = stack
    eng, ora = build_pair(cfg, params)
    rng = np.random.default_rng(1)
    conv1 = rng.integers(0, cfg.vocab_size, 53)
    eng.step_mixed([(10, conv1)], [])
    eng.close_session(10)          # pages stay alive in the radix tree
    assert eng.stats()["prefix_hit_tokens"] == 0
    conv2 = np.concatenate([conv1, rng.integers(0, cfg.vocab_size, 7)])
    assert eng.probe_prefix(conv2) == 48       # 3 full pages of turn 1
    r = eng.step_mixed([(11, conv2)], [])
    ro = ora.step_mixed([(11, conv2)], [])
    assert eng.stats()["prefix_hit_tokens"] == 48
    assert eng.history(11) == len(conv2)
    assert r.tokens[11] == ro.tokens[11]
    np.testing.assert_allclose(eng.last_logits[11], ora.last_logits[11],
                               **TOL)
    # decode continues seamlessly over the adopted pages
    d = eng.decode_batch([11], [r.tokens[11]], steps=3)
    do = ora.decode_batch([11], [ro.tokens[11]], steps=3)
    assert d[11] == do[11]
    eng.arena.audit()


def test_cow_fork_matches_independent_prefill(stack):
    """Satellite regression: two branches COW-forked from one prefix
    produce logits identical (1e-5) to two independently prefilled
    sessions, through decode across ≥ 2 page boundaries; exactly one
    page is COW-copied per diverging branch."""
    cfg, params = stack
    eng, ora = build_pair(cfg, params)
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, cfg.vocab_size, 27)   # partial boundary page
    r = eng.step_mixed([(1, prefix)], [])
    eng.fork_session(1, 2)
    assert eng.arena.pages_of(1) == eng.arena.pages_of(2)
    # both branches decode independently past TWO page boundaries
    # (27 + 22 = 49 crosses 32 and 48); distinct first tokens diverge
    # the branches immediately
    b1 = eng.decode_batch([1], [int(prefix[0])], steps=22)[1]
    b2 = eng.decode_batch([2], [int(prefix[1])], steps=22)[2]
    assert eng.stats()["pages_cow_forked"] >= 1
    assert eng.arena.pages_of(1) != eng.arena.pages_of(2)
    eng.arena.audit()
    # oracle: two slot-engine sessions prefilled independently
    o = ora.step_mixed([(1, prefix), (2, prefix)], [])
    o1 = ora.decode_batch([1], [int(prefix[0])], steps=22)[1]
    o2 = ora.decode_batch([2], [int(prefix[1])], steps=22)[2]
    assert b1 == o1 and b2 == o2
    np.testing.assert_allclose(eng.last_logits[1], ora.last_logits[1],
                               **TOL)
    np.testing.assert_allclose(eng.last_logits[2], ora.last_logits[2],
                               **TOL)


def test_pad_rows_only_touch_scratch_page(stack):
    """Page hygiene: a padded mixed tick (bucket tail + dummy rows)
    leaves every page except the step's own new pages and the reserved
    scratch page bit-identical."""
    cfg, params = stack
    eng, _ = build_pair(cfg, params)
    rng = np.random.default_rng(3)
    eng.step_mixed([(1, rng.integers(0, cfg.vocab_size, 21))], [])
    own = set(eng.arena.pages_of(1))
    before = jax.tree.map(np.array, eng.arena.arena)
    r = eng.step_mixed([(2, rng.integers(0, cfg.vocab_size, 5))], [])
    touched = set(eng.arena.pages_of(2)) | {eng.arena.scratch}
    after = jax.tree.map(np.array, eng.arena.arena)
    keep = np.asarray(sorted(set(range(eng.arena.num_pages + 1))
                             - touched), np.int32)
    assert own <= set(keep.tolist())
    for cb, ca in zip(before, after):
        for part in ("k", "v"):
            np.testing.assert_array_equal(cb[part][:, keep],
                                          ca[part][:, keep])
    eng.arena.audit()


def test_paged_interpret_backend_parity(stack):
    """The paged engine under forced-pallas interpret mode matches the
    jnp-oracle backend on a mixed prefill + decode schedule."""
    cfg, params = stack
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab_size, 19)
    outs = {}
    for backend in ("pallas", "ref"):
        try:
            kernel_ops.set_backend(backend)
            eng, _ = build_pair(cfg, params)
            r = eng.step_mixed([(1, toks)], [])
            d = eng.decode_batch([1], [r.tokens[1]], steps=2)
            outs[backend] = (r.tokens[1], d[1],
                             np.array(eng.last_logits[1]))
        finally:
            kernel_ops.set_backend(None)
    assert outs["pallas"][0] == outs["ref"][0]
    assert outs["pallas"][1] == outs["ref"][1]
    np.testing.assert_allclose(outs["pallas"][2], outs["ref"][2],
                               **TOL_INTERPRET)


def test_paged_engine_guards():
    """§12: paged_kv now covers every packed_ok config (windowed rings,
    SSM state pages) but still demands a causal decoder stack AND the
    packed + arena execution paths — collisions raise a clear
    ValueError at construction, not a deep kernel assert."""
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    with pytest.raises(ValueError, match="dense gather fallback"):
        Engine(cfg, params, EngineConfig(paged_kv=True, packed=False))
    with pytest.raises(ValueError, match="dense gather fallback"):
        Engine(cfg, params, EngineConfig(paged_kv=True,
                                         arena_decode=False))
    with pytest.raises(ValueError, match="dense gather fallback"):
        Engine(cfg, params, EngineConfig(paged_kv=True,
                                         arena_prefill=False))
    ecfg = get_smoke("hubert-xlarge")            # encoder-only
    eparams, _ = tr.init_params(ecfg, KEY)
    with pytest.raises(ValueError, match="causal decoder stack"):
        Engine(ecfg, eparams, EngineConfig(paged_kv=True))
    # formerly-excluded architectures now construct paged by default
    for arch in ("mamba2-2.7b", "mixtral-8x7b"):
        acfg = get_smoke(arch)
        aparams, _ = tr.init_params(acfg, KEY)
        aeng = Engine(acfg, aparams, EngineConfig(num_slots=4, max_len=64))
        assert aeng._paged
