"""Unit tests for the dry-run analysis tooling (no 512-device init:
pure text parsing + spec helpers)."""
import sys

import pytest

# import the parser without triggering the XLA_FLAGS side effect twice —
# dryrun sets env at import; harmless under JAX_PLATFORMS=cpu with the
# backend already initialized by conftest
from repro.launch.dryrun import collective_bytes  # noqa: E402
from repro.models.config import SHAPES, shape_by_name
from repro.launch.specs import train_accum
from repro.configs import get_config

HLO = """\
HloModule jit_step

%body.1 (arg: (f32[8,128], f32[])) -> (f32[8,128], f32[]) {
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups=...
  ROOT %t = tuple(...)
}

%cond.1 (arg: (f32[8,128], f32[])) -> pred[] {
  ROOT %lt = pred[] compare(...)
}

ENTRY %main.42 (p0: f32[8,128]) -> f32[8,128] {
  %ag = bf16[16,256]{1,0} all-gather(%p), channel_id=1
  %w = (f32[8,128], f32[]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[8,128] get-tuple-element(%w)
}
"""


def test_collective_parser_structural_attribution():
    out = collective_bytes(HLO, depth_factors=(10,))
    # entry all-gather: 16*256*2 bytes, wire x1, factor 1
    assert out["all-gather"] == 16 * 256 * 2
    # body all-reduce: 8*128*4 bytes, wire x2, x10 loop iterations
    assert out["all-reduce"] == 8 * 128 * 4 * 2 * 10
    assert out["total"] == out["all-gather"] + out["all-reduce"]


def test_collective_parser_nested_depths():
    hlo = HLO.replace(
        "%ar = f32[8,128]{1,0} all-reduce(%x), replica_groups=...",
        "%ar = f32[8,128]{1,0} all-reduce(%x), replica_groups=...\n"
        "  %w2 = (f32[4]) while(%i2), condition=%cond.1, body=%inner.9")
    hlo += """
%inner.9 (a: f32[4]) -> f32[4] {
  %rs = f32[4,4]{1,0} reduce-scatter(%y)
}
"""
    out = collective_bytes(hlo, depth_factors=(10, 7))
    assert out["reduce-scatter"] == 4 * 4 * 4 * 10 * 7


def test_shapes_registry():
    assert {s.name for s in SHAPES} == {"train_4k", "prefill_32k",
                                        "decode_32k", "long_500k"}
    assert shape_by_name("train_4k").tokens == 4096 * 256
    with pytest.raises(KeyError):
        shape_by_name("nope")


def test_train_accum_scales_with_model_size():
    small = get_config("qwen3-4b")
    big = get_config("jamba-v0.1-52b")
    a_small, mb_small = train_accum(shape_by_name("train_4k"), small)
    a_big, mb_big = train_accum(shape_by_name("train_4k"), big)
    assert a_small == 4 and mb_small == 64
    assert a_big == 8 and mb_big == 32
