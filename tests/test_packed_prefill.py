"""Padding-free packed prefill: engine parity with the pure forward,
token-bucket compile-cache growth, padding counters, ladder packing,
AWD packed batching, and executor donation-flag handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.awd import AWDConfig, AWDScheduler
from repro.core.buckets import BucketGrid, TokenBucketLadder
from repro.core.request import Request
from repro.models import transformer as tr
from repro.serving import Engine, EngineConfig, PackedBucketExecutor
from repro.serving.executor import resolve_donation

KEY = jax.random.key(3)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_smoke("qwen3-4b")
    params, _ = tr.init_params(cfg, KEY)
    return cfg, params


def packed_engine(cfg, params, **kw):
    defaults = dict(num_slots=8, max_len=64, packed=True,
                    token_buckets=(64, 128, 256))
    defaults.update(kw)
    return Engine(cfg, params, EngineConfig(**defaults))


def greedy(params, cfg, seq):
    lo, _, _ = tr.forward(params, cfg, tokens=jnp.asarray(seq, jnp.int32)[None])
    return int(jnp.argmax(lo[0, -1]))


# ---------------------------------------------------------------- engine


@pytest.mark.parametrize("arch", ["qwen3-4b", "qwen2.5-14b"])
def test_packed_matches_pure_forward(arch):
    """Mixed-length packed batch + decode + packed re-prefill all agree
    with the unbatched pure forward (qk_norm and qkv_bias variants)."""
    rng = np.random.default_rng(0)
    cfg = get_smoke(arch)
    params, _ = tr.init_params(cfg, KEY)
    eng = packed_engine(cfg, params)
    lens = [7, 23, 12]
    seqs = [rng.integers(0, cfg.vocab_size, l) for l in lens]
    out = eng.prefill_packed([0, 1, 2], seqs)
    for i, s in enumerate(seqs):
        assert out[i] == greedy(params, cfg, list(s))
    dec = eng.decode_batch([0], [out[0]], steps=2)
    t2 = rng.integers(0, cfg.vocab_size, 9)
    out2 = eng.prefill_packed([0, 1], [t2, rng.integers(0, cfg.vocab_size, 5)])
    ctx = list(seqs[0]) + [out[0]] + dec[0][:1] + list(t2)
    assert out2[0] == greedy(params, cfg, ctx)


def test_packed_compile_cache_keyed_on_token_bucket(qwen):
    """Different length MIXES under one total-token bucket share ONE
    compiled shape; the dense grid compiles one shape per (L, B)."""
    cfg, params = qwen
    rng = np.random.default_rng(1)
    eng = packed_engine(cfg, params)
    mixes = [[7, 23, 12], [40], [3, 3, 3, 3], [16, 16]]   # all ≤ 64 total
    s = 0
    for mix in mixes:
        eng.prefill_packed(list(range(s, s + len(mix))),
                           [rng.integers(0, cfg.vocab_size, l) for l in mix])
        for sess in range(s, s + len(mix)):
            eng.close_session(sess)
        s += len(mix)
    st = eng.stats()
    assert st["packed_shapes"] == 1
    assert eng.packed_executor.hits == len(mixes) - 1
    # one more mix in a bigger bucket → exactly one more shape
    eng.prefill_packed([90, 91], [rng.integers(0, cfg.vocab_size, 61),
                                  rng.integers(0, cfg.vocab_size, 40)])
    assert eng.stats()["packed_shapes"] == 2


def test_packed_beats_grid_padding(qwen):
    """Acceptance: the mixed batch (7, 23, 61, 12) pads ≥2× less through
    the packed path than through the (L, B) grid."""
    cfg, params = qwen
    rng = np.random.default_rng(2)
    lens = [7, 23, 61, 12]
    seqs = [rng.integers(0, cfg.vocab_size, l) for l in lens]

    eng = packed_engine(cfg, params, max_len=128, token_buckets=(64, 128, 256))
    eng.prefill_packed([0, 1, 2, 3], seqs)
    packed_pad = eng.packed_executor.padded_tokens

    grid_bucket = eng.grid.nearest_graph(lens)
    eng2 = Engine(cfg, params, EngineConfig(num_slots=8, max_len=128,
                                            paged_kv=False))
    eng2.prefill_batch([0, 1, 2, 3], seqs, bucket=grid_bucket.key)
    dense_pad = eng2.executor.padded_tokens

    assert sum(lens) == eng.packed_executor.useful_tokens
    assert dense_pad >= 2 * packed_pad, (dense_pad, packed_pad)


def test_packed_fallback_paths(qwen):
    """Capability routing (§7): every CAUSAL arch is packed-servable
    (mamba rides the SSM state arena); encoder-only models raise; and
    off-ladder totals still fall back to the dense path."""
    cfg, params = qwen
    rng = np.random.default_rng(3)
    # mamba: arena-resident packed serving by default
    mcfg = get_smoke("mamba2-2.7b")
    mparams, _ = tr.init_params(mcfg, KEY)
    meng = packed_engine(mcfg, mparams)
    assert meng.packed_executor is not None
    out = meng.prefill_packed([0], [rng.integers(0, mcfg.vocab_size, 6)])
    assert 0 in out
    assert meng.packed_executor.total_tokens > 0
    # encoder-only (no causal decode loop) is the remaining hard wall
    with pytest.raises(ValueError):
        PackedBucketExecutor(get_smoke("hubert-xlarge"))
    # off-ladder total → dense fallback, counters stay on the dense side
    # (a slot-arena concern: the paged pool splits instead, §12)
    eng = packed_engine(cfg, params, token_buckets=(16,), max_len=64,
                        paged_kv=False)
    eng.prefill_packed([0], [rng.integers(0, cfg.vocab_size, 30)])
    assert eng.packed_executor.total_tokens == 0
    assert eng.executor.total_tokens == 30


# ---------------------------------------------------------------- ladder


def test_token_ladder_lookup():
    lad = TokenBucketLadder((64, 128, 256), max_seqs=4)
    assert lad.bucket_for(1) == 64
    assert lad.bucket_for(64) == 64
    assert lad.bucket_for(65) == 128
    assert lad.bucket_for(256) == 256
    assert lad.bucket_for(257) is None
    assert lad.covers(256) and not lad.covers(300)
    assert lad.padding_waste([7, 23, 12]) == pytest.approx(1 - 42 / 64)


# ------------------------------------------------------------------- awd


def test_awd_packed_emits_token_buckets():
    grid = BucketGrid()
    awd = AWDScheduler(grid, AWDConfig(packed=True, token_buckets=(64, 128),
                                       packed_max_seqs=8))
    reqs = [Request(new_tokens=l, arrival=0.0) for l in [7, 23, 31]]
    batch, _ = awd.decide(list(reqs), now=1.0, force=True)
    assert batch is not None and batch.is_packed and batch.uses_graph
    assert batch.token_bucket == 64
    assert batch.padded_tokens == 64
    assert all(r.used_graph and r.padded_to is None for r in batch.requests)


def test_awd_mixed_emit_shrinks_fusion_to_fit_ladder():
    """A near-full batch plus a decode backlog must fuse FEWER decodes
    rather than falling off the packed path entirely: 126 prefill
    tokens + backlog 4 busts the 128 bucket, so exactly 2 fuse."""
    awd = AWDScheduler(BucketGrid(), AWDConfig(packed=True,
                                               token_buckets=(64, 128),
                                               packed_max_seqs=16))
    awd.note_decode_backlog(4)
    batch, _ = awd.decide([Request(new_tokens=126, arrival=0.0)], now=1.0,
                          force=True)
    assert batch is not None and batch.is_packed
    assert batch.token_bucket == 128
    assert batch.decode_tokens == 2
    assert batch.tokens + batch.decode_tokens <= batch.token_bucket


def test_awd_packed_profitability_guard():
    """A batch too small for the token bucket flunks max_pad_ratio and
    falls back to the dense (L, B) grid — a captured shape still beats
    an eager compile of the exact batch shape."""
    grid = BucketGrid()
    awd = AWDScheduler(grid, AWDConfig(packed=True, token_buckets=(512,),
                                       max_pad_ratio=1.5))
    batch, _ = awd.decide([Request(new_tokens=8, arrival=0.0)], now=1.0,
                          force=True)
    assert batch is not None and batch.token_bucket is None
    assert batch.uses_graph and (batch.bucket_len, batch.bucket_depth) == (8, 1)
    # off-grid AND off-bucket → standard unpadded kernel
    awd2 = AWDScheduler(grid, AWDConfig(packed=True, token_buckets=(512,),
                                        max_pad_ratio=1.5))
    batch2, _ = awd2.decide([Request(new_tokens=5, arrival=0.0)], now=1.0,
                            force=True)
    assert batch2 is not None and not batch2.uses_graph
    assert batch2.token_bucket is None and batch2.bucket_len is None


# -------------------------------------------------------------- donation


def test_resolve_donation_respects_explicit_flag():
    # default: backend heuristic (CPU in tests → False)
    assert resolve_donation(None) == (jax.default_backend() == "tpu")
    # explicit choice wins on every backend — never silently dropped
    assert resolve_donation(True) is True
    assert resolve_donation(False) is False


def test_executor_donation_applied_on_cpu(qwen):
    """donate_cache=True must actually donate (the old code silently
    disabled it off-TPU): the input cache buffer is invalidated."""
    cfg, params = qwen
    from repro.serving.executor import BucketExecutor
    ex = BucketExecutor(cfg, donate_cache=True)
    assert ex.donate_cache is True
    caches = tr.init_cache(cfg, 1, 16)
    tokens = jnp.zeros((1, 4), jnp.int32)
    positions = jnp.tile(jnp.arange(4), (1, 1))
    ex.prefill(params, tokens, positions, caches, jnp.asarray([3]))
    leaf = jax.tree.leaves(caches)[0]
    assert leaf.is_deleted()

    ex2 = BucketExecutor(cfg, donate_cache=False)
    assert ex2.donate_cache is False
    caches2 = tr.init_cache(cfg, 1, 16)
    ex2.prefill(params, tokens, positions, caches2, jnp.asarray([3]))
    assert not jax.tree.leaves(caches2)[0].is_deleted()
